"""Control plane: registry set/reset, rules, intent language, policies."""
import pytest

from repro.core import (AgentRule, Controller, Granularity, IntentError,
                        Registry, RequestRule, RuleTable, compile_intent)
from repro.core.metrics import CentralPoller, Collector, StateStore
from repro.core.types import Message
from repro.sim.clock import EventLoop


class FakeKnobbed:
    def __init__(self, name="eng", kind="llm"):
        self.name = name
        self.kind = kind
        self.values = {"max_num_seqs": 8, "temperature": 0.0}
        self._defaults = {}

    def card(self):
        from repro.core.types import AgentCard
        return AgentCard(name=self.name, kind=self.kind,
                         knobs=dict(self.values), metrics=("queue_len",),
                         capabilities=("kv_transfer",))

    def get_param(self, k):
        return self.values[k]

    def set_param(self, k, v):
        if k not in self.values:
            raise KeyError(k)
        self._defaults.setdefault(k, self.values[k])
        self.values[k] = v

    def reset_param(self, k):
        if k in self._defaults:
            self.values[k] = self._defaults[k]


def _controller(objs=()):
    loop = EventLoop()
    reg = Registry()
    for o in objs:
        reg.register(o)
    store = StateStore()
    poller = CentralPoller(store)
    c = Controller(loop, reg, poller, interval=0.05)
    return loop, reg, store, poller, c


# ---------------------------------------------------------------------------
# Registry (Table-1 surface)
# ---------------------------------------------------------------------------

def test_registry_set_reset_roundtrip():
    eng = FakeKnobbed()
    _, reg, *_ = _controller([eng])
    reg.set("eng", "max_num_seqs", 4)
    assert eng.values["max_num_seqs"] == 4
    reg.reset("eng", "max_num_seqs")
    assert eng.values["max_num_seqs"] == 8


def test_registry_discovery():
    eng = FakeKnobbed("a", "llm")
    tool = FakeKnobbed("b", "tool")
    _, reg, *_ = _controller([eng, tool])
    assert reg.of_kind("llm") == ["a"]
    assert set(reg.with_capability("kv_transfer")) == {"a", "b"}
    with pytest.raises(ValueError):
        reg.register(FakeKnobbed("a"))           # duplicate


def test_unknown_knob_raises():
    eng = FakeKnobbed()
    _, reg, *_ = _controller([eng])
    with pytest.raises(KeyError):
        reg.set("eng", "nonsense", 1)


# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------

def _msg(session="s0", task="t0", speculative=False):
    return Message(src="a", dst="b", payload={"session": session},
                   task_id=task, speculative=speculative)


def test_request_rule_routing_last_wins():
    rt = RuleTable()
    rt.install(RequestRule(session="s0", route_to="i0"))
    rt.install(RequestRule(session="s0", route_to="i1"))
    assert rt.route_for(_msg()) == "i1"
    assert rt.route_for(_msg(session="other")) is None


def test_request_rule_blocking_speculative():
    rt = RuleTable()
    rt.install(RequestRule(speculative=True, block=True))
    assert rt.blocked(_msg(speculative=True))
    assert not rt.blocked(_msg(speculative=False))
    rt.remove_request_rules(lambda r: r.block)
    assert not rt.blocked(_msg(speculative=True))


def test_agent_rule_knob_updates():
    r = AgentRule(target="dev->*", granularity=Granularity.BATCH, pace=0.01)
    upd = r.knob_updates()
    assert upd == {"granularity": Granularity.BATCH, "pace": 0.01}


def test_rule_table_version_bumps_on_remove():
    rt = RuleTable()
    v0 = rt.version
    rt.install(RequestRule(session="s0", route_to="i0"))
    assert rt.version == v0 + 1
    # remove bumps the version even when the predicate matches nothing —
    # routers re-pump their held messages off this signal
    rt.remove_request_rules(lambda r: False)
    assert rt.version == v0 + 2
    removed = rt.remove_request_rules(lambda r: r.route_to == "i0")
    assert removed == 1
    assert rt.version == v0 + 3
    assert rt.route_for(_msg()) is None


def test_rule_table_last_match_wins_across_fields():
    rt = RuleTable()
    rt.install(RequestRule(session="*", route_to="wide"))
    rt.install(RequestRule(session="s0", route_to="narrow"))
    rt.install(RequestRule(task="t0", route_to="by-task"))
    # most recently installed matching rule wins, regardless of how
    # specific an earlier rule was
    assert rt.route_for(_msg(session="s0", task="t0")) == "by-task"
    assert rt.route_for(_msg(session="s0", task="tX")) == "narrow"
    assert rt.route_for(_msg(session="sX", task="tX")) == "wide"
    # rules without route_to never win route_for
    rt.install(RequestRule(session="s0", block=True))
    assert rt.route_for(_msg(session="s0", task="tX")) == "narrow"


def test_rule_table_blocked_and_route_interplay():
    rt = RuleTable()
    rt.install(RequestRule(session="s0", route_to="i0"))
    rt.install(RequestRule(session="s0", block=True))
    m = _msg(session="s0")
    # a block rule holds the message even though a route rule matches:
    # routers check blocked() first, so route_for is moot while blocked
    assert rt.blocked(m)
    assert rt.route_for(m) == "i0"
    rt.remove_request_rules(lambda r: r.block)
    assert not rt.blocked(m)
    assert rt.route_for(m) == "i0"
    # a single rule can both block and carry a route: once the block is
    # lifted (rule removed), the route dies with it
    rt2 = RuleTable()
    rt2.install(RequestRule(session="s1", route_to="i1", block=True))
    m1 = _msg(session="s1")
    assert rt2.blocked(m1) and rt2.route_for(m1) == "i1"
    rt2.remove_request_rules(lambda r: r.block)
    assert not rt2.blocked(m1) and rt2.route_for(m1) is None


def test_agent_rule_admit_priority_min_applied_to_dst_engine():
    """Regression (ISSUE-5 satellite): ``admit_priority_min`` is
    documented as 'applied to the dst engine' but ``knob_updates()``
    (channel knobs only) silently dropped it — installing the rule
    through the controller must land it on the destination engines."""
    from repro.core.dataplane import Channel
    from repro.serving.router import Router
    from repro.sim.clock import EventLoop as _EL
    from repro.sim.network import Link

    eng = FakeKnobbed("tester-0")
    eng.values["admit_priority_min"] = 0
    loop = _EL()
    router = Router(loop, "tester-router")
    router.add_instance(eng)
    link = Link(loop, bandwidth=1e9, proc_time=0.0, name="l")
    chan = Channel(loop, link, "dev", router, name="dev->tester")
    _, reg, store, poller, c = _controller([eng])
    reg.register(chan)
    from repro.core.controller import ControlContext
    ctx = ControlContext(c)
    ctx.install(AgentRule(target="dev->*", granularity=Granularity.BATCH,
                          admit_priority_min=2))
    # channel knobs applied to the matching channel...
    assert chan.granularity is Granularity.BATCH
    # ...and the admission floor landed on the engine behind the router
    assert eng.values["admit_priority_min"] == 2
    # non-matching targets stay untouched
    eng.values["admit_priority_min"] = 0
    ctx.install(AgentRule(target="other->*", admit_priority_min=3))
    assert eng.values["admit_priority_min"] == 0


def test_agent_rule_reapplies_to_later_scale_ups():
    """An installed AgentRule must keep holding after autoscale: a
    replica spawned post-install receives the admission floor through
    ``Controller.reapply_agent_rules`` (wired into ElasticGroup)."""
    from repro.agents.pipeline import AgenticPipeline, PipelineConfig
    from repro.core.controller import ControlContext

    p = AgenticPipeline(PipelineConfig(n_testers=1))
    ctx = ControlContext(p.controller)
    ctx.install(AgentRule(target="dev->tester", admit_priority_min=2))
    assert p.registry.get_param("tester-0", "admit_priority_min") == 2
    new = p.elastic.scale_up()
    assert p.registry.get_param(new, "admit_priority_min") == 2


# ---------------------------------------------------------------------------
# Controller loop + context
# ---------------------------------------------------------------------------

def test_controller_polls_and_acts():
    eng = FakeKnobbed()
    loop, reg, store, poller, c = _controller([eng])
    col = Collector()
    poller.attach(col)
    col.gauge("eng.queue_len", 12, 0.0)

    from repro.core.controller import Policy

    class P(Policy):
        def on_tick(self, ctx):
            if ctx.metric("eng.queue_len", "last") > 10:
                ctx.set("eng", "max_num_seqs", 2)

    c.install(P())
    c.start()
    loop.run_until(0.2)
    assert eng.values["max_num_seqs"] == 2
    kinds = [a.kind for a in c.action_log()]
    assert "set" in kinds
    # idempotent set: only ONE action despite many ticks
    assert kinds.count("set") == 1


# ---------------------------------------------------------------------------
# Intent language
# ---------------------------------------------------------------------------

def test_intent_parse_and_fire():
    eng = FakeKnobbed()
    loop, reg, store, poller, c = _controller([eng])
    col = Collector()
    poller.attach(col)
    col.gauge("eng.queue_len", 20, 0.0)
    pol = compile_intent("""
# keep things sane
objective: maximize throughput under p95(lat) <= 2.0
rule shrink: when mean(eng.queue_len) > 10 => set eng.max_num_seqs 2
rule grow hold 1.0: when mean(eng.queue_len) <= 10 => reset eng.max_num_seqs
""")
    assert pol.objective.direction == "maximize"
    c.install(pol)
    c.start()
    loop.run_until(0.2)
    assert eng.values["max_num_seqs"] == 2
    assert pol.stats()["shrink"] >= 1


def test_intent_guarded_first_match_wins():
    eng = FakeKnobbed()
    loop, reg, store, poller, c = _controller([eng])
    col = Collector()
    poller.attach(col)
    col.gauge("eng.queue_len", 20, 0.0)
    pol = compile_intent("""
rule a: when mean(eng.queue_len) > 15 => set eng.max_num_seqs 1
rule b: when mean(eng.queue_len) > 5 => set eng.max_num_seqs 99
""")
    c.install(pol)
    c.start()
    loop.run_until(0.1)
    assert eng.values["max_num_seqs"] == 1      # rule b never fired
    assert pol.stats()["b"] == 0


def test_intent_conjunction_and_windows():
    pol = compile_intent(
        "rule r: when mean(a.x, 2.0) > 1 and p95(a.y) <= 3 => note hello")
    term = pol.rules[0].cond.terms[0]
    assert term.window == 2.0 and term.cmp == ">"


def test_intent_syntax_errors():
    with pytest.raises(IntentError):
        compile_intent("rule r: when garbage => set a.b 1")
    with pytest.raises(IntentError):
        compile_intent("rule r: when mean(x) > 1 => frobnicate y")
    with pytest.raises(IntentError):
        compile_intent("objective: minimize nothing")    # no rules


def test_intent_unobserved_metric_does_not_fire():
    eng = FakeKnobbed()
    loop, reg, store, poller, c = _controller([eng])
    pol = compile_intent(
        "rule r: when mean(ghost.metric) > 0 => set eng.max_num_seqs 1")
    c.install(pol)
    c.start()
    loop.run_until(0.2)
    assert eng.values["max_num_seqs"] == 8      # NaN comparisons are False
