"""Serving layer: sim engine semantics, real JAX engine generation,
KV extract/inject parity, router, KV transfer timing."""
import jax
import numpy as np
import pytest

from repro import models
from repro.configs import get_config
from repro.core.metrics import Collector
from repro.core.types import Message, Request, RequestState
from repro.serving.engine import Engine
from repro.serving.engine_sim import SimEngine
from repro.serving.kv_transfer import KVTransferManager, SessionDirectory
from repro.serving.router import Router
from repro.serving.scheduler import SchedulerConfig
from repro.sim.clock import EventLoop
from repro.sim.costmodel import CostModel


# ---------------------------------------------------------------------------
# Sim engine
# ---------------------------------------------------------------------------

def _sim(loop=None, **sched_kw):
    loop = loop or EventLoop()
    cm = CostModel(get_config("agent-7b"), chips=4)
    cfg = SchedulerConfig(max_slots=4, num_pages=256, **sched_kw)
    return loop, SimEngine(loop, cm, cfg, collector=Collector())


def test_sim_engine_completes_requests():
    loop, eng = _sim()
    reqs = [Request(prompt_len=64, max_new_tokens=8) for _ in range(6)]
    for r in reqs:
        eng.submit(r)
    loop.run_until(120.0)
    assert all(r.state == RequestState.FINISHED for r in reqs)
    assert all(r.generated == 8 for r in reqs)
    assert eng.tokens_generated == 48
    # latency metrics recorded
    assert len(eng.finished) == 6
    assert all(r.first_token_time is not None for r in reqs)


def test_sim_engine_continuous_batching_faster_than_serial():
    loop1, eng1 = _sim()
    batch = [Request(prompt_len=32, max_new_tokens=16) for _ in range(4)]
    for r in batch:
        eng1.submit(r)
    loop1.run_until(1e5)
    t_batched = max(r.finish_time for r in batch)

    loop2, eng2 = _sim()
    t = 0.0
    serial = []
    for i in range(4):
        r = Request(prompt_len=32, max_new_tokens=16)
        serial.append(r)

    def submit_next(i=0):
        if i < 4:
            eng2.on_finish = lambda *_: submit_next(i + 1)
            eng2.submit(serial[i])
    submit_next()
    loop2.run_until(1e5)
    t_serial = max(r.finish_time for r in serial)
    assert t_batched < 0.5 * t_serial      # slot batching amortizes weights


def test_sim_engine_pause_resume():
    loop, eng = _sim()
    r = Request(prompt_len=16, max_new_tokens=4)
    eng.set_param("paused", True)
    eng.submit(r)
    loop.run_until(10.0)
    assert r.state != RequestState.FINISHED
    eng.set_param("paused", False)
    loop.run_until(50.0)
    assert r.state == RequestState.FINISHED


def test_sim_engine_knob_shim():
    loop, eng = _sim()
    eng.set_param("max_num_seqs", 2)
    assert eng.scheduler.cfg.max_slots == 2
    eng.reset_param("max_num_seqs")
    assert eng.scheduler.cfg.max_slots == 4
    with pytest.raises(KeyError):
        eng.set_param("no_such_knob", 1)
    card = eng.card()
    assert card.kind == "llm" and "kv_transfer" in card.capabilities


# ---------------------------------------------------------------------------
# Real JAX engine
# ---------------------------------------------------------------------------

def _real_engine():
    cfg = get_config("tiny-agent")
    params = models.init(cfg, jax.random.key(0))
    sched = SchedulerConfig(max_slots=2, num_pages=64, max_context=128)
    return cfg, Engine(cfg, params, sched, name="real0")


def test_real_engine_generates():
    cfg, eng = _real_engine()
    prompts = [np.arange(5, 13) % cfg.vocab, np.arange(3, 10) % cfg.vocab]
    reqs = [Request(prompt_len=len(p), max_new_tokens=6,
                    prompt_tokens=np.asarray(p, np.int32)) for p in prompts]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    for r in reqs:
        assert r.state == RequestState.FINISHED
        assert len(r.output_tokens) == 6
        assert all(0 <= t < cfg.vocab for t in r.output_tokens)


def test_real_engine_greedy_deterministic():
    cfg, eng1 = _real_engine()
    _, eng2 = _real_engine()
    p = np.arange(7, 23) % cfg.vocab
    outs = []
    for eng in (eng1, eng2):
        r = Request(prompt_len=len(p), max_new_tokens=8,
                    prompt_tokens=np.asarray(p, np.int32))
        eng.submit(r)
        eng.run_until_idle()
        outs.append(r.output_tokens)
    assert outs[0] == outs[1]


def test_real_engine_kv_extract_inject_parity():
    """Migrating a sequence between engines preserves greedy decoding."""
    cfg, eng1 = _real_engine()
    _, eng2 = _real_engine()
    p = np.arange(1, 17) % cfg.vocab

    # run to completion on engine 1 (reference)
    ref = Request(prompt_len=len(p), max_new_tokens=10,
                  prompt_tokens=np.asarray(p, np.int32))
    eng1.submit(ref)
    eng1.run_until_idle()

    # same prompt on a fresh engine; migrate MID-FLIGHT after 4 tokens
    # (the slot must still be live — finishing releases it)
    _, engA = _real_engine()
    r = Request(prompt_len=len(p), max_new_tokens=10,
                prompt_tokens=np.asarray(p, np.int32))
    engA.submit(r)
    while r.generated < 4:
        engA.step()
    state = engA.extract_state(r)
    first4 = list(r.output_tokens)
    engA.scheduler.preempt_one()          # drop it from the source
    assert r.output_tokens == []           # emission record fully reset
    r.generated = 4                        # resume point rides the state
    r.prefilled = r.prompt_len
    ok = eng2.scheduler.admit_direct(r)
    assert ok
    eng2.inject_state(r, state)
    eng2.run_until_idle()
    assert first4 + r.output_tokens == ref.output_tokens


# ---------------------------------------------------------------------------
# Router + KV transfer
# ---------------------------------------------------------------------------

class _Sink:
    def __init__(self, name):
        self.name = name
        self.msgs = []

    def deliver(self, m):
        self.msgs.append(m)

    def load(self):
        return float(len(self.msgs))


def test_router_static_affinity_and_rules():
    loop = EventLoop()
    r = Router(loop, policy="static")
    a, b = _Sink("i0"), _Sink("i1")
    r.add_instance(a)
    r.add_instance(b)
    m1 = Message(src="s", dst="r", payload={"session": "x"}, task_id="t1")
    m2 = Message(src="s", dst="r", payload={"session": "x"}, task_id="t2")
    r.deliver(m1)
    r.deliver(m2)
    # same session -> same instance
    assert (len(a.msgs), len(b.msgs)) in ((2, 0), (0, 2))
    # an installed rule overrides
    from repro.core.rules import RequestRule
    r.rules.install(RequestRule(session="x", route_to="i1"))
    m3 = Message(src="s", dst="r", payload={"session": "x"}, task_id="t3")
    r.deliver(m3)
    assert b.msgs and b.msgs[-1] is m3


def test_router_least_loaded():
    loop = EventLoop()
    r = Router(loop, policy="least_loaded")
    a, b = _Sink("i0"), _Sink("i1")
    a.msgs = [None] * 5                       # pre-loaded
    r.add_instance(a)
    r.add_instance(b)
    m = Message(src="s", dst="r", payload={"session": "y"}, task_id="t")
    r.deliver(m)
    assert b.msgs == [m]


def test_router_remove_instance_redispatches_held_and_drops_pins():
    """Removing an instance must (a) drop stale fallback session pins
    targeting it and (b) re-dispatch held/blocked messages, even when no
    new deliver bumps the rule-table version afterwards."""
    from repro.core.rules import RequestRule
    loop = EventLoop()
    r = Router(loop, policy="static")
    a, b = _Sink("i0"), _Sink("i1")
    r.add_instance(a)
    r.add_instance(b)
    # pin a session to each instance via the fallback hash
    sessions = [f"s{i}" for i in range(8)]
    for s in sessions:
        r.deliver(Message(src="x", dst="r", payload={"session": s},
                          task_id=s))
    pinned_to_a = [s for s, i in r._session_pin.items() if i == "i0"]
    assert pinned_to_a
    # hold a message behind a block rule
    r.rules.install(RequestRule(session=pinned_to_a[0], block=True))
    held = Message(src="x", dst="r", payload={"session": pinned_to_a[0]},
                   task_id="held")
    r.deliver(held)
    assert held in r._held
    # unblock (version bump happens, but no new deliver arrives) ...
    r.rules.remove_request_rules(lambda rule: rule.block)
    # ... then the pinned instance dies
    n_b = len(b.msgs)
    r.remove_instance("i0")
    assert all(i != "i0" for i in r._session_pin.values())
    assert held not in r._held
    assert b.msgs[-1] is held and len(b.msgs) == n_b + 1
    # re-delivery of an old i0 session lands on the survivor
    r.deliver(Message(src="x", dst="r", payload={"session": pinned_to_a[0]},
                      task_id="again"))
    assert b.msgs[-1].task_id == "again"


def test_router_held_message_survives_remove_last_then_add():
    """A message held while the fleet is momentarily empty must be
    re-dispatched when a replacement instance registers, and the
    ``held_count`` gauge must make the whole window observable (the
    failover-drill satellite)."""
    from repro.core.rules import RequestRule
    loop = EventLoop()
    col = Collector()
    r = Router(loop, policy="static", collector=col)
    a = _Sink("i0")
    r.add_instance(a)
    r.rules.install(RequestRule(session="s", block=True))
    held = Message(src="x", dst="r", payload={"session": "s"},
                   task_id="held")
    r.deliver(held)
    assert held in r._held
    assert r.held_count == 1
    assert col.last("router.held_count") == 1
    r.rules.remove_request_rules(lambda rule: rule.block)
    r.remove_instance("i0")              # fleet empty: nothing to pump to
    assert held in r._held
    assert col.last("router.held_count") == 1
    b = _Sink("i1")
    r.add_instance(b)                    # replacement arrives
    assert b.msgs == [held] and not r._held
    assert col.last("router.held_count") == 0


def test_router_empty_fleet_holds_instead_of_crashing():
    """Delivering into a momentarily-empty fleet (remove-last before the
    replacement registers) holds the message rather than raising, so an
    elastic-group failover never drops traffic."""
    loop = EventLoop()
    col = Collector()
    r = Router(loop, policy="least_loaded", collector=col)
    a = _Sink("i0")
    r.add_instance(a)
    r.remove_instance("i0")
    m = Message(src="x", dst="r", payload={"session": "s"}, task_id="t")
    r.deliver(m)                         # no instances: held, not raised
    assert r.held_count == 1
    assert col.last("router.held_count") == 1
    b = _Sink("i1")
    r.add_instance(b)
    assert b.msgs == [m] and r.held_count == 0


def test_kv_transfer_timing_and_residency():
    loop = EventLoop()
    d = SessionDirectory()
    kvx = KVTransferManager(loop, d, bytes_fn=lambda ctx: ctx * 1000,
                            bandwidth=1e6, latency=0.0)
    d.ensure("s0", "i0")
    d.grow("s0", 500)                          # 500k bytes -> 0.5 s
    t = kvx.transfer("s0", "i0", "i1")
    assert abs(t - 0.5) < 1e-6
    assert not d.resident("s0", "i1", now=0.0)
    assert abs(kvx.wait_time("s0", "i1") - 0.5) < 1e-6
    loop.run_until(1.0)
    assert d.resident("s0", "i1", now=1.0)
    assert d.get("s0").instance == "i1"
    assert kvx.wait_time("s0", "i1") == 0.0


def test_kv_transfers_serialize_on_link():
    loop = EventLoop()
    d = SessionDirectory()
    kvx = KVTransferManager(loop, d, bytes_fn=lambda ctx: 1_000_000,
                            bandwidth=1e6, latency=0.0)
    for s in ("a", "b"):
        d.ensure(s, "i0")
        d.grow(s, 1)
    t1 = kvx.transfer("a", "i0", "i1")
    t2 = kvx.transfer("b", "i0", "i1")
    assert abs(t1 - 1.0) < 1e-6 and abs(t2 - 2.0) < 1e-6   # FIFO pipe
