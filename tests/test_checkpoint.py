"""Checkpoint manager: atomicity, keep-K GC, async writes, resharding."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(x=1.0):
    return {"params": {"w": np.full((8, 4), x, np.float32),
                       "b": np.arange(4, dtype=np.int32)},
            "step": np.asarray(7)}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    t = _tree(2.5)
    mgr.save(10, t, meta={"data_step": 10})
    got, meta = mgr.restore(10, t)
    np.testing.assert_array_equal(got["params"]["w"], t["params"]["w"])
    np.testing.assert_array_equal(got["params"]["b"], t["params"]["b"])
    assert meta["data_step"] == 10


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(float(s)))
    assert mgr.steps() == [3, 4]
    got, _ = mgr.restore(4, _tree())
    assert got["params"]["w"][0, 0] == 4.0


def test_incomplete_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(1, _tree(1.0))
    # simulate a crashed writer: directory without _COMPLETE
    bad = tmp_path / "step_2"
    bad.mkdir()
    (bad / "arrays.npz").write_bytes(b"garbage")
    assert mgr.latest_step() == 1
    step, tree, _ = mgr.restore_latest(_tree())
    assert step == 1


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(5, _tree(5.0), blocking=False)
    mgr.wait()
    assert mgr.steps() == [5]


def test_restore_latest_none_when_empty(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    assert mgr.restore_latest(_tree()) is None


def test_restore_casts_dtypes(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=1)
    t = {"w": np.ones((4,), np.float32)}
    mgr.save(1, t)
    like = {"w": jnp.zeros((4,), jnp.bfloat16)}
    got, _ = mgr.restore(1, like)
    assert got["w"].dtype == jnp.bfloat16


def test_leaf_count_mismatch_raises(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=1)
    mgr.save(1, {"a": np.ones(3)})
    with pytest.raises(AssertionError):
        mgr.restore(1, {"a": np.ones(3), "b": np.ones(2)})
