"""Integration: the full agentic pipeline under control — mini versions
of the three paper experiments plus speculative gating and the A2A
protocol facade."""
import statistics


from repro.agents import (AgenticPipeline, PipelineConfig, TaskSpec,
                          WorkloadConfig)
from repro.agents.workloads import (OpenLoopSource, Phase, PhasedLoad,
                                    _dispatch_done, launch_clients)
from repro.core.policies import (AdaptiveGranularityPolicy,
                                 LoadBalancePolicy, SpeculativeGatePolicy)
from repro.core.types import Granularity


def test_pipeline_completes_tasks_all_granularities():
    for g in Granularity:
        p = AgenticPipeline(PipelineConfig(granularity=g))
        for i in range(3):
            p.submit(TaskSpec(session=f"s{i}", n_functions=2,
                              func_tokens=16, test_tokens=8))
        p.run(until=30.0)
        assert len(p.done) == 3, g
        assert all(s.finished_at > s.submitted_at for s in p.done)


def test_latency_ordering_low_load():
    """At low load finer granularity must not be slower (overlap wins)."""
    lat = {}
    for g in (Granularity.BATCH, Granularity.STREAM):
        p = AgenticPipeline(PipelineConfig(granularity=g, stream_chunk=2))
        launch_clients(p, WorkloadConfig(n_clients=1, think_time=0.2),
                       stop_at=15.0)
        p.run(until=25.0)
        lat[g] = statistics.mean(p.latencies())
    assert lat[Granularity.STREAM] < lat[Granularity.BATCH]


def test_adaptive_switches_with_load():
    p = AgenticPipeline(PipelineConfig(granularity=Granularity.PIPELINE,
                                       stream_chunk=2))
    pol = AdaptiveGranularityPolicy("dev->tester", ["tester-0"],
                                    stream_below=2.0, batch_above=10.0)
    p.controller.install(pol)
    load = PhasedLoad(p, WorkloadConfig(think_time=0.2),
                      [Phase(6.0, 1), Phase(8.0, 32), Phase(6.0, 1)])
    load.start()
    p.run(until=22.0)
    modes = [g for _, g in pol.switches]
    assert Granularity.STREAM in modes      # low-load phase
    assert Granularity.BATCH in modes       # burst phase
    assert len(p.done) > 10


def test_load_balance_improves_tail_latency():
    def run(mode):
        p = AgenticPipeline(PipelineConfig(
            granularity=Granularity.PIPELINE, n_testers=2,
            dev_chips=8, tester_chips=2))
        pol = LoadBalancePolicy([t.name for t in p.testers], mode=mode,
                                imbalance_min=2.0, cooldown=1.0)
        p.controller.install(pol)
        # adversarial: all sessions hash to tester-0 (crc32 % 2 == 0)
        hot = ["sess-4", "sess-5", "sess-6", "sess-7", "sess-14",
               "sess-15", "sess-16", "sess-17"]
        src = OpenLoopSource(p, hot, 0.6,
                             WorkloadConfig(n_functions=6, func_tokens=32,
                                            test_tokens=32), t_end=20.0)
        src.start()
        p.run(until=40.0)
        lats = sorted(p.latencies())
        return lats[int(0.9 * len(lats)) - 1], pol.migrations

    p90_none, m0 = run("none")
    p90_lb, m1 = run("hints")
    assert m0 == 0 and m1 > 0
    assert p90_lb < p90_none            # controller reduces tail latency


def test_speculative_gate_policy():
    p = AgenticPipeline(PipelineConfig(granularity=Granularity.BATCH))
    pol = SpeculativeGatePolicy("dev->tester", ["tester-0"],
                                gate_above=2.0)
    p.controller.install(pol)
    # load up the tester, then submit a speculative task
    for i in range(8):
        p.submit(TaskSpec(session=f"s{i}", n_functions=4, func_tokens=32,
                          test_tokens=32))
    p.controller.start()
    p.loop.run_until(2.0)
    p.submit(TaskSpec(session="spec", n_functions=1, func_tokens=8,
                      test_tokens=8, speculative=True))
    p.loop.run_until(4.0)
    assert p.channel.gate_speculative or p.channel.held_count >= 0
    p.loop.run_until(120.0)
    assert len(p.done) == 9             # gated task eventually completes


def test_kv_transfer_metrics_exported():
    p = AgenticPipeline(PipelineConfig(n_testers=2))
    pol = LoadBalancePolicy([t.name for t in p.testers], mode="hints",
                            imbalance_min=0.0, cooldown=0.0)
    p.controller.install(pol)
    launch_clients(p, WorkloadConfig(n_clients=6, think_time=0.1),
                   stop_at=10.0)
    p.run(until=20.0)
    if p.kvx.transfers:
        assert p.kvx.bytes_moved > 0
        assert p.collector.last("kvx.transfer_bytes") is not None


def test_a2a_protocol_facade():
    from repro.agents.protocol import A2AClient
    p = AgenticPipeline(PipelineConfig(granularity=Granularity.BATCH))
    client = A2AClient.from_agent_card(p.registry, "tester-0", p.channel)
    assert client.card.kind == "llm"
    # app "streams", data plane batches — late binding in action
    stream = client.send_message_streaming(session="a2a-sess",
                                           n_functions=1, func_tokens=12,
                                           test_tokens=8)
    for _ in range(12):
        stream.push(1)
    stream.end_unit()
    stream.close()
    p.run(until=0.5)
    assert p.channel.msgs_sent <= 2     # batched despite streaming API
