"""Integration: workflow graphs compiled into serving topologies —
stage runtime semantics (fan-out, join, branch, tool), critical-path
deadlines on real requests, stage-aware tier routing, and the stage
knob/intent surface."""
import math

import pytest

from repro.agents import (AgenticPipeline, GraphBurst, GraphTask,
                          PipelineConfig, StageKind, TierSpec,
                          WorkflowConfig, WorkflowGraph, WorkflowPipeline,
                          debate, deep_review, fig1, map_reduce)
from repro.core import compile_intent

SMALL_POOL = {"large": TierSpec("agent-7b", chips=4, replicas=2, slots=16),
              "small": TierSpec("agent-1b", chips=1, replicas=2, slots=16)}


def build(graph, **kw):
    kw.setdefault("tiers", dict(SMALL_POOL))
    return AgenticPipeline.build(graph, WorkflowConfig(**kw))


def run_tasks(wp, n=4, until=120.0):
    burst = GraphBurst(wp, n)
    burst.start()
    wp.run(until=until)
    return burst


# ---------------------------------------------------------------------------
# build() dispatch + compilation
# ---------------------------------------------------------------------------


def test_build_dispatches_fig1_to_classic_pipeline():
    p = AgenticPipeline.build(fig1())
    assert isinstance(p, AgenticPipeline)
    assert p.graph.template == "fig1"
    assert p.controller.graph is p.graph
    with pytest.raises(TypeError):
        AgenticPipeline.build(fig1(), WorkflowConfig())
    with pytest.raises(TypeError):
        AgenticPipeline.build(map_reduce(), PipelineConfig())


def test_compiled_topology_registers_everything():
    wp = build(map_reduce(width=3))
    names = set(wp.registry.names())
    # stage controllables, channels, pool engines, router
    assert {"stage.planner", "stage.map", "stage.reduce"} <= names
    assert {"planner->map", "map->reduce"} <= names
    assert "workflow-router" in names and "wf-large-0" in names
    card = wp.registry.card("stage.map")
    assert card.kind == "stage"
    assert set(card.knobs) == {"model_tier", "deadline_slack",
                               "join_timeout", "width"}


# ---------------------------------------------------------------------------
# stage runtime semantics
# ---------------------------------------------------------------------------


def test_all_prebuilt_graphs_complete_tasks():
    for g in (map_reduce(width=4), deep_review(depth=3), debate()):
        wp = build(g)
        run_tasks(wp, n=5)
        assert len(wp.done) == 5, g.name
        assert all(t.finished_at > t.submitted_at for t in wp.done)


def test_fanout_issues_width_calls_and_join_waits():
    wp = build(map_reduce(width=6))
    run_tasks(wp, n=2)
    assert wp.stages["map"].calls == 2 * 6
    assert wp.stages["reduce"].calls == 2      # one joined call per task


def test_branch_routes_to_exactly_one_successor():
    g = debate()
    g.stages["verdict"].branch_fn = lambda tid: 0   # always "accept"
    wp = build(g)
    run_tasks(wp, n=4)
    assert len(wp.done) == 4
    assert wp.stages["accept"].calls == 4
    assert wp.stages["revise"].calls == 0


def test_tool_stage_runs_through_tool_agent():
    wp = build(debate())
    run_tasks(wp, n=3)
    assert wp.stages["factcheck"].tool.calls == 3
    assert wp.registry.get("factcheck.tool").kind == "tool"


def test_join_timeout_releases_partial_fanin():
    """A join whose second input is very slow dispatches after
    join_timeout with what arrived — and the straggler's late arrival
    doesn't wedge the task's completion refcount."""
    g = WorkflowGraph("straggle")
    g.stage("fast", out_tokens=8)
    g.stage("slow", out_tokens=2048)     # decodes far longer than fast
    g.stage("join", kind=StageKind.JOIN, join_timeout=0.5, out_tokens=8)
    g.add_edge("fast", "join")
    g.add_edge("slow", "join")
    wp = build(g)
    wp.submit(GraphTask(session="s", prompt_tokens=32))
    wp.run(until=300.0)
    assert len(wp.done) == 1
    assert not wp._pending                     # refcount fully drained
    assert wp.stages["join"].calls == 1


def test_join_k_fires_on_first_input():
    g = WorkflowGraph("k1")
    g.stage("a", out_tokens=8)
    g.stage("b", out_tokens=8)
    g.stage("j", kind=StageKind.JOIN, join_k=1, out_tokens=8)
    g.add_edge("a", "j")
    g.add_edge("b", "j")
    wp = build(g)
    run_tasks(wp, n=3)
    assert len(wp.done) == 3
    assert wp.stages["j"].calls == 3           # ran once per task, not twice


# ---------------------------------------------------------------------------
# critical-path scheduling
# ---------------------------------------------------------------------------


def test_requests_carry_propagated_deadlines():
    seen = {}
    wp = build(deep_review(depth=2))
    orig = wp.route_call

    def spy(msg):
        req = msg.payload["request"]
        seen.setdefault(req.stage, req.deadline)
        orig(msg)

    wp.route_call = spy
    run_tasks(wp, n=1)
    assert len(wp.done) == 1
    # deadlines are finite and monotone along the chain
    order = ["author", "reviewer-0", "reviewer-1", "editor"]
    assert all(math.isfinite(seen[s]) for s in order)
    assert all(seen[a] <= seen[b] for a, b in zip(order, order[1:]))


def test_critical_path_off_leaves_defaults():
    wp = build(map_reduce(width=2), critical_path=False)
    reqs = []
    orig = wp.route_call
    wp.route_call = lambda m: (reqs.append(m.payload["request"]), orig(m))
    run_tasks(wp, n=2)
    assert all(r.deadline == math.inf for r in reqs)
    assert all(r.meta.get("cp_remaining", 0.0) == 0.0 for r in reqs)


def test_scheduler_orders_edf_within_priority():
    from repro.core.types import Request
    from repro.serving.scheduler import Scheduler, SchedulerConfig
    s = Scheduler(SchedulerConfig(max_slots=1))
    late = Request(prompt_len=8, max_new_tokens=1, deadline=9.0)
    soon = Request(prompt_len=8, max_new_tokens=1, deadline=1.0)
    nodl = Request(prompt_len=8, max_new_tokens=1)
    for r in (nodl, late, soon):
        s.submit(r)
    assert s.waiting == [soon, late, nodl]
    # cp_remaining breaks deadline ties toward the longest remaining path
    a = Request(prompt_len=8, max_new_tokens=1, deadline=5.0)
    b = Request(prompt_len=8, max_new_tokens=1, deadline=5.0)
    b.meta["cp_remaining"] = 10.0
    s2 = Scheduler(SchedulerConfig(max_slots=1))
    s2.submit(a)
    s2.submit(b)
    assert s2.waiting == [b, a]


# ---------------------------------------------------------------------------
# stage-aware tiering + the knob/intent surface
# ---------------------------------------------------------------------------


def test_stage_aware_routing_honors_model_tier_knob():
    wp = build(map_reduce(width=4, worker_tier="small"))
    run_tasks(wp, n=4)
    small = {w.name for w in wp.workers if w.tier == "small"}
    small_calls = sum(wp.router.routed[n] for n in small)
    assert wp.router.tier_routed > 0
    assert small_calls >= 4 * 4                # every map call landed small
    # re-tier through the registry: planner calls move tiers too
    wp2 = build(map_reduce(width=2))
    wp2.registry.set("stage.planner", "model_tier", "small")
    assert wp2.registry.get_param("stage.planner", "model_tier") == "small"
    with pytest.raises(ValueError):
        wp2.registry.set("stage.planner", "model_tier", "gigantic")


def test_retier_shifts_critical_path_estimates():
    wp = build(deep_review(depth=3))
    before = wp._cp_total
    for i in range(3):
        wp.registry.set(f"stage.reviewer-{i}", "model_tier", "small")
    assert wp._cp_total != before              # estimates recomputed


def test_intent_stage_selectors_end_to_end():
    wp = build(map_reduce(width=6))
    intent = compile_intent("""
objective: minimize p95(workflow.task_latency)
rule slow on stage map.p95 > 0.01 hold 1:
    => set stage map.model_tier small
rule unused: when p95(stage map.latency, 5.0) > 1e9
    => reset stage map.model_tier
""")
    wp.controller.install(intent)
    run_tasks(wp, n=6)
    assert intent.stats()["slow"] >= 1
    assert wp.registry.get_param("stage.map", "model_tier") == "small"
    sets = [a for a in wp.controller.action_log("set")
            if a.target == "stage.map"]
    assert sets and "model_tier=small" in sets[0].detail


def test_stage_tier_policy_downshifts_on_breach():
    from repro.core.policies import StageTierPolicy
    wp = build(map_reduce(width=6))
    pol = StageTierPolicy(["map"], slow_above=0.01, dwell=0.0)
    wp.controller.install(pol)
    run_tasks(wp, n=6)
    assert any(tier == "small" for _, _, tier in pol.shifts)
    assert wp.registry.get_param("stage.map", "model_tier") == "small"


def test_fig1_requests_are_stage_stamped():
    from repro.agents import TaskSpec
    p = AgenticPipeline(PipelineConfig())
    p.submit(TaskSpec(session="s", n_functions=2, func_tokens=16,
                      test_tokens=8))
    p.run(until=15.0)
    assert len(p.done) == 1
    assert p.done[0].finished_at > p.done[0].submitted_at
    dev = p.developer.engine.finished
    tst = p.testers[0].engine.finished
    assert dev and all(r.stage == "developer" for r in dev)
    assert tst and all(r.stage == "tester" for r in tst)
