"""Sharding rules: logical-axis mapping, divisibility fallbacks, joint
axes, cache specs.  Mesh-shape logic only — no multi-device runtime."""
import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed import sharding as shd


class FakeMesh:
    """Shape-only stand-in (spec_for never touches devices)."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)
        self.devices = np.empty(tuple(axes.values()), dtype=object)


MESH = FakeMesh(data=16, model=16)
POD = FakeMesh(pod=2, data=16, model=16)


def test_tp_axes_map_to_model():
    s = shd.spec_for(("embed", "heads", None), (16384, 128, 128), MESH)
    assert s == P("data", "model", None)


def test_kv_heads_fallback_replicates():
    # GQA: 8 kv heads on a 16-way model axis -> replicated (Megatron KV
    # replication), embed still FSDP
    s = shd.spec_for(("embed", "kv_heads", None), (16384, 8, 128), MESH)
    assert s == P("data", None, None)


def test_mesh_axis_used_once_per_tensor():
    # experts take 'model' first; ff must not reuse it
    s = shd.spec_for(("experts", "embed", "ff"), (128, 7168, 4864), MESH)
    assert s == P("model", "data", None)


def test_joint_fsdp_over_pod_and_data():
    s = shd.spec_for(("embed", "vocab"), (16384, 128256), POD)
    assert s == P(("pod", "data"), "model")
    # non-divisible by 32 falls back to data-only
    s2 = shd.spec_for(("embed", None), (16 * 17, 4), POD)
    assert s2 == P("data", None)


def test_stacked_param_leading_dims_replicated():
    cfg = get_config("llama3-405b")
    specs = shd.param_pspecs(cfg, MESH)
    wq = specs["decoder"][0]["e0"]["attn"]["wq"]
    assert wq[0] is None                 # layer-stack dim
    assert "model" in wq and "data" in wq


def test_batch_pspec_divisibility():
    assert shd.batch_pspec(MESH, batch_size=256) == P("data")
    assert shd.batch_pspec(POD, batch_size=256) == P(("pod", "data"))
    assert shd.batch_pspec(POD, batch_size=16) == P("data")   # 16 % 32 != 0
    assert shd.batch_pspec(MESH, batch_size=1) == P(None)


def _kv_leaves(specs):
    """Ring-KV specs: 'model' lands on the seq (-3) or heads (-2) dim."""
    return [s for s in jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
        if len(s) >= 4 and "model" in s]


def test_cache_specs_decode_batch_and_seq():
    cfg = get_config("llama3-405b")          # kv=8 < model=16
    specs = shd.cache_pspecs(cfg, batch=128, max_context=32896, mesh=MESH)
    kv = _kv_leaves(specs)
    assert kv, "no kv leaves found"
    for s in kv:
        assert "data" in s                   # batch sharded
        # GQA fallback: sequence (not heads) carries the model axis
        assert s[-3] == "model" and s[-2] is None


def test_cache_specs_gqa16_heads_tp():
    cfg = get_config("gemma3-27b")           # kv=16 == model
    specs = shd.cache_pspecs(cfg, batch=128, max_context=4096, mesh=MESH)
    kv = _kv_leaves(specs)
    assert kv
    for s in kv:
        assert s[-2] == "model"              # heads dim TP'd
        assert "data" in s                   # batch sharded


def test_cache_specs_long_context_seq_sharding():
    cfg = get_config("h2o-danube-3-4b")
    specs = shd.cache_pspecs(cfg, batch=1, max_context=524416, mesh=MESH,
                             shard_seq=True)
    ring = [s for s in jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, P)) if len(s) == 5]
    assert ring
    for s in ring:
        assert s[-3] == "data"               # seq over data, batch=1
        assert s[1] is None                  # batch dim unshardable


def test_input_specs_all_cells_build():
    """input_specs/input_pspecs construct for every (arch, shape)."""
    from repro.configs import ARCHS, SHAPES, shape_applicable
    from repro.launch import specs as specs_mod
    n = 0
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, _ = shape_applicable(cfg, shape)
            if not ok:
                continue
            ins = specs_mod.input_specs(cfg, shape)
            ps = specs_mod.input_pspecs(cfg, shape, MESH)
            assert jax.tree.structure(ins) is not None
            n += 1
    assert n == 34          # 40 cells - 6 long_500k skips


def test_long_500k_cell_count():
    from repro.configs import ARCHS, SHAPES, shape_applicable
    skips = [a for a in ARCHS
             if not shape_applicable(get_config(a),
                                     SHAPES["long_500k"])[0]]
    assert len(skips) == 6
