"""Intent language v2: error paths, `on` event triggers (MetricBus and
named events), and the scale/gate/transfer actions."""
import pytest

from repro.agents import AgenticPipeline, PipelineConfig, TaskSpec
from repro.core import (Controller, IntentError, MetricBus, Registry,
                        compile_intent)
from repro.core.metrics import CentralPoller, Collector, StateStore
from repro.sim.clock import EventLoop

from tests.test_controller import FakeKnobbed


def _controller(objs=(), bus=None):
    loop = EventLoop()
    reg = Registry()
    for o in objs:
        reg.register(o)
    store = StateStore()
    poller = CentralPoller(store)
    c = Controller(loop, reg, poller, interval=0.05, bus=bus)
    return loop, reg, store, poller, c


# ---------------------------------------------------------------------------
# Error paths
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("program,fragment", [
    ("rule r: when mean(x) > 1 => frobnicate y", "unknown action"),
    ("rule r: when mean(x) > 1 => scale grp lots", "scale needs"),
    ("rule r: when mean(x) > 1 => gate ch maybe", "gate needs"),
    ("rule r: when mean(x) > 1 => transfer s src", "unknown action"),
    ("rule r: when median(x) > 1 => note hi", "unknown aggregation"),
    ("rule r: when garbage => note hi", "bad condition term"),
    ("rule r: when mean(x) > 1 => set nodot 1", "set needs TARGET.KNOB"),
    ("rule r: when mean(x) > 1 => reset nodot", "reset needs TARGET.KNOB"),
    ("rule r on !!bad!!: => note hi", "bad trigger"),
    ("rule r: => note hi", "needs a 'when' condition or an 'on' trigger"),
    ("this is not a rule", "cannot parse"),
    ("objective: maximize throughput", "no rules"),
])
def test_intent_error_paths(program, fragment):
    with pytest.raises(IntentError) as ei:
        compile_intent(program)
    assert fragment in str(ei.value)


def test_intent_empty_action_list_rejected():
    with pytest.raises(IntentError):
        compile_intent("rule r: when mean(x) > 1 => ")


# ---------------------------------------------------------------------------
# Parsing v2 clauses
# ---------------------------------------------------------------------------

def test_trigger_parsing_threshold_and_named():
    pol = compile_intent("""
rule a on eng.queue_len > 12 hold 3: => note burst
rule b on task_start: => note started
rule c hold 2 on eng.queue_len < 1: when mean(eng.queue_len) < 1 => note calm
""")
    a, b, c = pol.rules
    assert a.trigger.metric == "eng.queue_len" and a.trigger.value == 12
    assert a.hold == 3.0 and a.cond is None
    assert b.trigger.event == "task_start"
    assert c.hold == 2.0 and c.trigger.cmp == "<" and c.cond is not None


# ---------------------------------------------------------------------------
# Event semantics
# ---------------------------------------------------------------------------

def test_bus_trigger_fires_between_polls():
    eng = FakeKnobbed()
    bus = MetricBus()
    loop, reg, store, poller, c = _controller([eng], bus=bus)
    col = Collector(bus=bus)
    poller.attach(col)
    pol = compile_intent(
        "rule spike on eng.queue_len > 10: => set eng.max_num_seqs 2")
    c.install(pol)                        # subscribes; controller NOT started
    assert pol.rules[0].bus_bound
    assert [s.metric for s in bus.subscriptions()] == ["eng.queue_len"]
    col.gauge("eng.queue_len", 20, 0.01)  # push: no tick ever runs
    loop.run_until(0.02)                  # deferred action executes
    assert eng.values["max_num_seqs"] == 2
    assert c.ticks == 0                   # purely event-driven
    assert [a.kind for a in c.actions] == ["event", "set"]


def test_bus_trigger_hold_is_refire_cooldown():
    eng = FakeKnobbed()
    bus = MetricBus()
    loop, reg, store, poller, c = _controller([eng], bus=bus)
    col = Collector(bus=bus)
    poller.attach(col)
    pol = compile_intent(
        "rule spike on eng.queue_len > 10 hold 5: => note fired")
    c.install(pol)
    for i in range(5):                    # burst of samples: one fire
        col.gauge("eng.queue_len", 20 + i, 0.01 * (i + 1))
    loop.run_until(0.1)
    assert pol.stats()["spike"] == 1
    col.gauge("eng.queue_len", 0, 0.2)    # dip changes nothing:
    col.gauge("eng.queue_len", 30, 0.3)   # still within the 5 s hold
    loop.run_until(0.4)
    assert pol.stats()["spike"] == 1
    # level-triggered: a SUSTAINED breach re-fires once the hold expires
    loop.run_until(5.5)                   # advance the control clock too
    col.gauge("eng.queue_len", 30, 5.5)
    loop.run_until(5.6)
    assert pol.stats()["spike"] == 2


def test_bus_trigger_without_hold_is_edge_triggered():
    eng = FakeKnobbed()
    bus = MetricBus()
    loop, reg, store, poller, c = _controller([eng], bus=bus)
    col = Collector(bus=bus)
    poller.attach(col)
    pol = compile_intent(
        "rule spike on eng.queue_len > 10: => note fired")
    c.install(pol)
    for i in range(5):                    # sustained breach: one edge
        col.gauge("eng.queue_len", 20, 0.01 * (i + 1))
    col.gauge("eng.queue_len", 0, 0.1)    # leaves region: re-arms
    col.gauge("eng.queue_len", 20, 0.2)   # second excursion
    loop.run_until(0.3)
    assert pol.stats()["spike"] == 2


def test_glob_subscription_cooldowns_are_per_instance():
    bus = MetricBus()
    fired = []
    bus.subscribe("tester-*.queue_len", above=10, cooldown=5.0, edge=False,
                  fn=lambda n, v, t: fired.append((n, t)))
    bus.publish("tester-0.queue_len", 20, 1.0)
    bus.publish("tester-1.queue_len", 20, 2.0)   # independent instance
    bus.publish("tester-0.queue_len", 20, 3.0)   # within tester-0 cooldown
    assert fired == [("tester-0.queue_len", 1.0),
                     ("tester-1.queue_len", 2.0)]


def test_edge_subscription_with_cooldown_stays_armed():
    # a cooldown-suppressed re-entry must NOT disarm the edge trigger
    bus = MetricBus()
    fired = []
    bus.subscribe("q", above=8, cooldown=5.0, edge=True,
                  fn=lambda n, v, t: fired.append(t))
    bus.publish("q", 9, 0.0)              # entry: fires
    bus.publish("q", 5, 1.0)              # leaves: re-arms
    bus.publish("q", 9, 2.0)              # re-entry inside cooldown: held
    bus.publish("q", 9, 3.0)              # still breached, still held
    bus.publish("q", 9, 6.0)              # cooldown over: breach not lost
    assert fired == [0.0, 6.0]


def test_glob_term_pools_fleet_metrics():
    eng = FakeKnobbed()
    loop, reg, store, poller, c = _controller([eng])
    col = Collector()
    poller.attach(col)
    col.gauge("tester-0.queue_len", 0, 0.0)
    col.gauge("tester-1.queue_len", 12, 0.0)   # one hot instance
    pol = compile_intent(
        "rule any_hot: when max(tester-*.queue_len) > 10"
        " => set eng.max_num_seqs 2")
    c.install(pol)
    c.start()
    loop.run_until(0.2)
    assert eng.values["max_num_seqs"] == 2
    # fleet-wide mean pools both series: (0 + 12) / 2
    assert store.get("tester-*.queue_len", "mean") == 6.0


def test_hold_given_twice_rejected():
    with pytest.raises(IntentError) as ei:
        compile_intent(
            "rule r hold 2 on eng.queue_len > 5 hold 4: => note hi")
    assert "'hold' given twice" in str(ei.value)


def test_trigger_degrades_to_tick_rule_without_bus():
    eng = FakeKnobbed()
    loop, reg, store, poller, c = _controller([eng], bus=None)
    col = Collector()
    poller.attach(col)
    col.gauge("eng.queue_len", 20, 0.0)
    pol = compile_intent(
        "rule spike on eng.queue_len > 10: => set eng.max_num_seqs 2")
    c.install(pol)
    assert not pol.rules[0].bus_bound
    c.start()
    loop.run_until(0.2)                   # interval path picks it up
    assert eng.values["max_num_seqs"] == 2


def test_named_event_trigger():
    eng = FakeKnobbed()
    loop, reg, store, poller, c = _controller([eng])
    pol = compile_intent(
        "rule hint on task_start: => set eng.temperature 1.0")
    c.install(pol)
    c.event("task_done")                  # wrong kind: no fire
    assert pol.stats()["hint"] == 0
    c.event("task_start", session="s0")
    assert pol.stats()["hint"] == 1
    assert eng.values["temperature"] == 1.0


def test_event_rule_when_guard_still_applies():
    eng = FakeKnobbed()
    bus = MetricBus()
    loop, reg, store, poller, c = _controller([eng], bus=bus)
    col = Collector(bus=bus)
    poller.attach(col)
    pol = compile_intent("""
rule spike on eng.queue_len > 10: when mean(eng.temperature_hint) > 5
    => set eng.max_num_seqs 2
""")
    c.install(pol)
    col.gauge("eng.queue_len", 20, 0.01)  # trigger fires, guard is NaN
    loop.run_until(0.1)
    assert eng.values["max_num_seqs"] == 8   # guard held the actions back


# ---------------------------------------------------------------------------
# scale / gate / transfer end-to-end on the real pipeline
# ---------------------------------------------------------------------------

def test_scale_action_from_bus_event_scales_fleet_and_audits():
    p = AgenticPipeline(PipelineConfig(n_testers=1))
    pol = compile_intent(
        "rule burst on tester-0.queue_len > 6 hold 4:"
        " => scale tester-group +1")
    p.controller.install(pol)
    for i in range(10):
        p.submit(TaskSpec(session=f"s{i}", n_functions=2, func_tokens=16,
                          test_tokens=16))
    p.run(until=8.0)
    assert p.registry.get_param("tester-group", "replicas") >= 2
    kinds = [a.kind for a in p.controller.actions]
    assert "event" in kinds and "scale" in kinds
    scale = next(a for a in p.controller.actions if a.kind == "scale")
    assert scale.target == "tester-group" and "replicas" in scale.detail


def test_gate_action_toggles_channel():
    p = AgenticPipeline(PipelineConfig(n_testers=1))
    pol = compile_intent("""
rule shut on task_start: => gate dev->tester on
""")
    p.controller.install(pol)
    p.controller.event("task_start", session="x")
    assert p.channel.gate_speculative is True
    assert any(a.kind == "set" and "gate_speculative" in a.detail
               for a in p.controller.actions)


def test_transfer_action_moves_session_state():
    p = AgenticPipeline(PipelineConfig(n_testers=2))
    p.directory.ensure("sx", "tester-0")
    p.directory.grow("sx", 256)
    pol = compile_intent(
        "rule mv on task_start: => transfer sx tester-0 tester-1")
    p.controller.install(pol)
    p.controller.event("task_start", session="sx")
    p.loop.run_until(5.0)
    assert p.directory.get("sx").instance == "tester-1"
    assert any(a.kind == "transfer" for a in p.controller.actions)


def test_scale_clamps_at_one_replica():
    p = AgenticPipeline(PipelineConfig(n_testers=1))
    pol = compile_intent("rule dn on task_start: => scale tester-group -3")
    p.controller.install(pol)
    p.controller.event("task_start")
    assert p.registry.get_param("tester-group", "replicas") == 1
    assert not any(a.kind == "scale" for a in p.controller.actions)
