"""KVTransferManager timing semantics (ISSUE-4 satellite): proactive vs
reactive landing, wait_time around delivery, link contention,
SessionDirectory.resident over the inflight window, and the
prefill→decode handoff pipeline's chunk/tail arithmetic."""
import pytest

from repro.serving.kv_transfer import KVTransferManager, SessionDirectory
from repro.sim.clock import EventLoop


def _kvx(bytes_per_ctx=1000, bandwidth=1e6, latency=0.0):
    loop = EventLoop()
    d = SessionDirectory()
    kvx = KVTransferManager(loop, d, bytes_fn=lambda c: c * bytes_per_ctx,
                            bandwidth=bandwidth, latency=latency)
    return loop, d, kvx


# ---------------------------------------------------------------------------
# session transfers
# ---------------------------------------------------------------------------

def test_proactive_vs_reactive_landing():
    """A proactive (hinted) transfer started dt before the request
    arrives lands dt earlier than a reactive one started on arrival —
    the transfer overlaps upstream generation instead of serializing."""
    # reactive: request arrives at t=0.3, transfer starts then
    loop, d, kvx = _kvx()
    d.ensure("s", "i0")
    d.grow("s", 500)                       # 0.5 s on the wire
    loop.run_until(0.3)
    t_reactive = kvx.transfer("s", "i0", "i1")
    assert t_reactive == pytest.approx(0.8)

    # proactive: hint fires at t=0, request arrives at t=0.3
    loop2, d2, kvx2 = _kvx()
    d2.ensure("s", "i0")
    d2.grow("s", 500)
    t_proactive = kvx2.transfer("s", "i0", "i1", proactive=True)
    assert t_proactive == pytest.approx(0.5)
    loop2.run_until(0.3)
    # at arrival time, only 0.2 s of the transfer remains exposed
    assert kvx2.wait_time("s", "i1") == pytest.approx(0.2)


def test_wait_time_before_and_after_delivery():
    loop, d, kvx = _kvx()
    d.ensure("s", "i0")
    d.grow("s", 1000)                      # 1.0 s
    assert kvx.wait_time("s", "i0") == 0.0          # already home
    assert kvx.wait_time("s", "i1") == float("inf")  # nothing on the way
    kvx.transfer("s", "i0", "i1")
    assert kvx.wait_time("s", "i1") == pytest.approx(1.0)
    assert kvx.wait_time("s", "i2") == float("inf")  # wrong destination
    loop.run_until(0.4)
    assert kvx.wait_time("s", "i1") == pytest.approx(0.6)
    loop.run_until(2.0)
    assert kvx.wait_time("s", "i1") == 0.0           # delivered
    assert d.get("s").instance == "i1"


def test_link_contention_two_sessions_share_link():
    """Two transfers on the same (src, dst) link serialize FIFO; a
    transfer on a different link is unaffected."""
    loop, d, kvx = _kvx()
    for s in ("a", "b", "c"):
        d.ensure(s, "i0")
        d.grow(s, 1000)
    t_a = kvx.transfer("a", "i0", "i1")
    t_b = kvx.transfer("b", "i0", "i1")    # queues behind a
    t_c = kvx.transfer("c", "i0", "i2")    # separate link: no queueing
    assert t_a == pytest.approx(1.0)
    assert t_b == pytest.approx(2.0)
    assert t_c == pytest.approx(1.0)
    # the queued transfer's wait_time reflects the serialized horizon
    assert kvx.wait_time("b", "i1") == pytest.approx(2.0)


def test_resident_around_inflight_window():
    loop, d, kvx = _kvx()
    d.ensure("s", "i0")
    d.grow("s", 1000)
    assert d.resident("s", "i0", now=0.0)
    kvx.transfer("s", "i0", "i1")
    # in flight: resident at neither destination time-point semantics —
    # the source still holds it, the destination not yet
    assert d.resident("s", "i0", now=0.5)
    assert not d.resident("s", "i1", now=0.5)
    # ready_at reached but callback not yet run: resident() is already
    # true by timestamp (the controller can route against it)
    assert d.resident("s", "i1", now=1.0)
    loop.run_until(1.5)
    assert d.resident("s", "i1", now=1.5)
    assert d.get("s").inflight_to is None  # window closed


def test_transfer_to_home_is_noop():
    loop, d, kvx = _kvx()
    d.ensure("s", "i0")
    d.grow("s", 500)
    called = []
    t = kvx.transfer("s", "i0", "i0", on_done=lambda: called.append(1))
    assert t == loop.now() and called == [1]
    assert kvx.transfers == 0              # nothing moved


# ---------------------------------------------------------------------------
# handoff pipeline timing
# ---------------------------------------------------------------------------

def test_handoff_progress_streams_incremental_chunks():
    loop, d, kvx = _kvx()
    kvx.start_handoff("r1", "p0", "d0")
    kvx.handoff_progress("r1", 200)        # 200k bytes -> 0.2 s
    rec = kvx.handoff_records["r1"]
    assert rec.streamed_tokens == 200
    assert rec.ready_at == pytest.approx(0.2)
    kvx.handoff_progress("r1", 500)        # +300k -> lands at 0.5
    assert rec.ready_at == pytest.approx(0.5)
    # regressing/duplicate progress is ignored
    kvx.handoff_progress("r1", 400)
    assert rec.streamed_tokens == 500
    assert kvx.handoff_bytes == pytest.approx(500_000)


def test_handoff_finish_tail_and_wait():
    loop, d, kvx = _kvx()
    kvx.start_handoff("r1", "p0", "d0")
    kvx.handoff_progress("r1", 800)
    # unfinished handoff: destination must keep waiting
    assert kvx.handoff_wait("r1", "d0") == float("inf")
    landed = []
    t = kvx.finish_handoff("r1", "p0", "d0", 1000,
                           on_ready=lambda: landed.append(loop.now()))
    assert t == pytest.approx(1.0)         # 800k streamed + 200k tail
    assert kvx.handoff_wait("r1", "d0") == pytest.approx(1.0)
    assert kvx.handoff_wait("r1", "other") == float("inf")
    loop.run_until(0.6)
    assert kvx.handoff_wait("r1", "d0") == pytest.approx(0.4)
    loop.run_until(2.0)
    assert landed == [pytest.approx(1.0)]
    assert kvx.handoff_wait("r1", "d0") == 0.0
    # no handoff record at all => locally resident by construction
    assert kvx.handoff_wait("never-started", "d0") == 0.0


def test_handoff_fully_streamed_tail_is_free():
    """When every chunk streamed during prefill, finish costs nothing
    beyond the last chunk's in-flight remainder."""
    loop, d, kvx = _kvx()
    kvx.start_handoff("r1", "p0", "d0")
    kvx.handoff_progress("r1", 1000)       # all of it, lands at 1.0
    loop.run_until(0.2)
    landed = []
    t = kvx.finish_handoff("r1", "p0", "d0", 1000,
                           on_ready=lambda: landed.append(loop.now()))
    assert t == pytest.approx(1.0)         # no new bytes; last chunk ETA
    loop.run_until(2.0)
    assert landed == [pytest.approx(1.0)]


def test_handoff_rehome_restreams():
    """If the pinned decode engine changed, already-streamed chunks are
    wasted and the full state restreams to the new destination."""
    loop, d, kvx = _kvx()
    kvx.start_handoff("r1", "p0", "d0")
    kvx.handoff_progress("r1", 600)
    t = kvx.finish_handoff("r1", "p0", "d1", 1000, on_ready=lambda: None)
    assert t == pytest.approx(1.0)         # full 1000 tokens on p0->d1
    rec = kvx.handoff_records["r1"]
    assert rec.dst == "d1" and rec.streamed_tokens == 1000


def test_handoff_chunks_contend_on_link():
    """Two concurrent handoffs between the same engine pair serialize
    on the shared link — chunk arithmetic includes the queueing."""
    loop, d, kvx = _kvx()
    kvx.start_handoff("r1", "p0", "d0")
    kvx.start_handoff("r2", "p0", "d0")
    kvx.handoff_progress("r1", 500)        # 0.0 - 0.5 on the link
    kvx.handoff_progress("r2", 500)        # queues: 0.5 - 1.0
    t1 = kvx.finish_handoff("r1", "p0", "d0", 500, on_ready=lambda: None)
    t2 = kvx.finish_handoff("r2", "p0", "d0", 500, on_ready=lambda: None)
    assert t1 == pytest.approx(0.5)
    assert t2 == pytest.approx(1.0)
