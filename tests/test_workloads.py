"""Direct coverage for the workload generators (agents/workloads.py):
closed-loop client lifecycle, phased ramp, open-loop arrivals, and the
workflow burst driver."""
import random

from repro.agents import AgenticPipeline, PipelineConfig
from repro.agents.workloads import (ClosedLoopClient, GraphBurst,
                                    OpenLoopSource, Phase, PhasedLoad,
                                    WorkloadConfig, launch_clients)


def small_pipeline(**kw):
    kw.setdefault("n_testers", 1)
    return AgenticPipeline(PipelineConfig(**kw))


def quick_cfg(**kw):
    kw.setdefault("n_functions", 2)
    kw.setdefault("func_tokens", 16)
    kw.setdefault("test_tokens", 8)
    kw.setdefault("think_time", 0.2)
    return WorkloadConfig(**kw)


# ---------------------------------------------------------------------------
# ClosedLoopClient
# ---------------------------------------------------------------------------


def test_closed_loop_start_stop_lifecycle():
    p = small_pipeline()
    c = ClosedLoopClient(p, "sess", quick_cfg(), random.Random(0))
    assert not c.active and c._timer is None
    c.start(delay=0.1)
    assert c.active and c._timer is not None
    p.run(until=10.0)
    assert c.submitted >= 1
    c.stop()
    assert not c.active


def test_stop_cancels_pending_timer():
    """stop() must cancel the in-flight think-timer, not just flip the
    flag — a stopped client leaves nothing live on the event loop."""
    p = small_pipeline()
    c = ClosedLoopClient(p, "sess", quick_cfg(think_time=5.0),
                         random.Random(0))
    c.start(delay=3.0)                 # pending start-timer, not yet fired
    ev = c._timer
    assert ev is not None and not ev.cancelled
    c.stop()
    assert c._timer is None and ev.cancelled
    p.run(until=30.0)
    assert c.submitted == 0            # the cancelled timer never fired


def test_stop_with_task_in_flight_does_not_rearm():
    """A client stopped while its task is still in flight must stay
    quiescent when the completion lands — no stray think-timer that a
    later start() could double up with."""
    p = small_pipeline()
    # default-size tasks take ~1s+; client start delay is <= 0.101s,
    # so at t=0.3 exactly one task is submitted and still in flight
    cs = launch_clients(p, WorkloadConfig(n_clients=1, think_time=0.1))
    p.run(until=0.3)
    c = cs[0]
    assert c.submitted >= 1 and c.completed == 0
    c.stop()
    p.run(until=60.0)                  # in-flight task completes
    assert c.completed >= 1
    assert c._timer is None            # _on_done did not re-arm
    assert c.submitted == 1            # and no further submissions


def test_closed_loop_respects_tasks_per_client():
    p = small_pipeline()
    cs = launch_clients(p, quick_cfg(n_clients=2, tasks_per_client=3))
    p.run(until=120.0)
    assert all(c.submitted == 3 for c in cs)
    assert all(c.completed == 3 for c in cs)
    assert len(p.done) == 6


def test_closed_loop_stops_at_stop_at():
    p = small_pipeline()
    cs = launch_clients(p, quick_cfg(), stop_at=5.0)
    p.run(until=40.0)
    assert all(c.submitted >= 1 for c in cs)
    # nothing was submitted after the cutoff
    assert all(t.submitted_at < 5.0 for t in p.done)


# ---------------------------------------------------------------------------
# PhasedLoad
# ---------------------------------------------------------------------------


def test_phased_load_ramps_clients_up_and_down():
    p = small_pipeline()
    load = PhasedLoad(p, quick_cfg(),
                      [Phase(4.0, 1), Phase(4.0, 4), Phase(4.0, 1)])
    load.start()
    active_at = {}
    for t in (2.0, 6.0, 10.0):
        p.loop.call_at(t, lambda t=t: active_at.__setitem__(
            t, sum(1 for c in load.clients if c.active)))
    p.run(until=13.0)
    assert active_at[2.0] == 1
    assert active_at[6.0] == 4
    assert active_at[10.0] == 1        # ramp back down deactivates 3
    assert load.boundaries == [0.0, 4.0, 8.0]
    assert len(p.done) > 0


def test_phased_load_stopped_clients_leave_no_timers():
    p = small_pipeline()
    load = PhasedLoad(p, quick_cfg(),
                      [Phase(3.0, 3), Phase(3.0, 1)])
    load.start()
    p.run(until=6.5)
    stopped = [c for c in load.clients if not c.active]
    assert stopped
    assert all(c._timer is None for c in stopped)


# ---------------------------------------------------------------------------
# OpenLoopSource
# ---------------------------------------------------------------------------


def test_open_loop_source_poisson_arrivals_bounded_by_t_end():
    p = small_pipeline()
    src = OpenLoopSource(p, ["a", "b"], rate_per_session=2.0,
                         cfg=quick_cfg(), t_end=6.0, seed=1)
    src.start()
    p.run(until=60.0)
    assert src.submitted > 0
    assert len(p.done) == src.submitted          # open loop fully drains
    assert all(t.submitted_at < 6.0 for t in p.done)


# ---------------------------------------------------------------------------
# GraphBurst
# ---------------------------------------------------------------------------


def test_graph_burst_submits_n_tasks():
    from repro.agents import map_reduce
    wp = AgenticPipeline.build(map_reduce(width=2))
    burst = GraphBurst(wp, n_tasks=5, stagger=0.1, seed=3)
    burst.start()
    wp.run(until=120.0)
    assert len(burst.tasks) == 5
    assert len(wp.done) == 5
    stamps = sorted(t.submitted_at for t in wp.done)
    assert stamps[0] < stamps[-1]                # staggered, not a spike
