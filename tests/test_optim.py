"""Optimizer: AdamW reference behaviour, int8 moments, quantization."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import (AdamWConfig, _dequantize, _quantize,
                               adamw_init, adamw_update)


def _params():
    k = jax.random.key(0)
    return {"w": jax.random.normal(k, (64, 256)),
            "b": jnp.zeros((256,)),
            "emb": jax.random.normal(jax.random.key(1), (100, 64))}


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.key(2), (33, 200)) * 3.0
    q = _quantize(x)
    back = _dequantize(q, x.shape)
    err = np.abs(np.asarray(back - x))
    # blockwise linear int8: error <= scale/2 per block
    assert err.max() <= float(jnp.abs(x).max()) / 127.0 + 1e-6
    assert q.q.dtype == jnp.int8


def test_quantize_handles_zeros_and_odd_shapes():
    for shape in [(1,), (5,), (3, 129), (2, 2, 130)]:
        x = jnp.zeros(shape)
        back = _dequantize(_quantize(x), shape)
        assert np.all(np.asarray(back) == 0)


def test_adamw_decreases_quadratic_loss():
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                      total_steps=1000, min_lr_frac=1.0)
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    state = adamw_init(params, cfg)
    loss = lambda p: jnp.sum(jnp.square(p["w"]))
    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, state, m = adamw_update(grads, state, params, cfg)
    assert float(loss(params)) < 1e-2


def test_adamw_int8_tracks_f32():
    """int8 moments stay close to the f32 trajectory."""
    f32 = AdamWConfig(lr=0.01, weight_decay=0.01, warmup_steps=0)
    i8 = AdamWConfig(lr=0.01, weight_decay=0.01, warmup_steps=0,
                     int8_moments=True)
    p1 = _params()
    p2 = jax.tree.map(jnp.array, p1)
    s1, s2 = adamw_init(p1, f32), adamw_init(p2, i8)
    loss = lambda p: jnp.mean(jnp.square(p["w"])) + jnp.mean(
        jnp.square(p["emb"] - 1.0))
    for _ in range(20):
        g1 = jax.grad(loss)(p1)
        g2 = jax.grad(loss)(p2)
        p1, s1, _ = adamw_update(g1, s1, p1, f32)
        p2, s2, _ = adamw_update(g2, s2, p2, i8)
    # int8 moments drift from the exact trajectory but stay close:
    # compare the *update direction*, not element-exact values
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
        denom = np.linalg.norm(a.ravel()) * np.linalg.norm(b.ravel())
        if denom < 1e-9:
            continue                    # untouched zero leaf (bias)
        cos = float(a.ravel() @ b.ravel() / denom)
        assert cos > 0.999, cos
        assert np.abs(a - b).max() < 0.2


def test_grad_clipping():
    cfg = AdamWConfig(lr=0.0, clip_norm=1.0, warmup_steps=0)
    params = {"w": jnp.ones((4,))}
    state = adamw_init(params, cfg)
    huge = {"w": jnp.full((4,), 1e6)}
    _, _, metrics = adamw_update(huge, state, params, cfg)
    assert metrics["grad_norm"] > 1e6          # reported pre-clip


def test_warmup_and_cosine_schedule():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    params = {"w": jnp.ones((2,))}
    state = adamw_init(params, cfg)
    lrs = []
    for _ in range(100):
        g = {"w": jnp.zeros((2,))}
        params, state, m = adamw_update(g, state, params, cfg)
        lrs.append(float(m["lr"]))
    assert lrs[0] < 0.2                          # warmup ramps
    assert abs(max(lrs) - 1.0) < 0.05            # peaks at lr
    assert lrs[-1] < 0.2                         # decays toward min frac
