"""Data pipeline: determinism, host sharding, restart-exactness."""
import numpy as np

from repro.data import DataConfig, TokenPipeline


def test_batch_determinism():
    cfg = DataConfig(vocab=1000, seq_len=64, global_batch=8, seed=3)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    for step in (0, 5, 17):
        b1, b2 = p1.batch_at(step), p2.batch_at(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=500, seq_len=32, global_batch=4)
    b = TokenPipeline(cfg).batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_host_sharding_disjoint_and_covering():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=8)
    full = TokenPipeline(cfg).batch_at(3)["tokens"]
    parts = []
    for h in range(4):
        c = DataConfig(vocab=1000, seq_len=16, global_batch=8,
                       host_index=h, host_count=4)
        parts.append(TokenPipeline(c).batch_at(3)["tokens"])
    assert all(p.shape == (2, 16) for p in parts)
    # each host's slice is distinct (different RNG stream)
    assert len({p.tobytes() for p in parts}) == 4


def test_prefetch_iteration_matches_batch_at():
    cfg = DataConfig(vocab=300, seq_len=16, global_batch=4, prefetch=2)
    p = TokenPipeline(cfg)
    it = iter(p)
    got = [next(it) for _ in range(3)]
    p.close()
    for i, b in enumerate(got):
        np.testing.assert_array_equal(b["tokens"], p.batch_at(i)["tokens"])


def test_restart_resumes_exact_stream():
    cfg = DataConfig(vocab=300, seq_len=16, global_batch=4)
    p = TokenPipeline(cfg)
    it = iter(p)
    seen = [next(it)["tokens"] for _ in range(5)]
    state = p.state_dict()
    p.close()

    q = TokenPipeline(cfg)
    qit = iter(q)
    for _ in range(5):
        next(qit)
    q.load_state(state)
    resumed = next(iter(q))["tokens"]
    np.testing.assert_array_equal(resumed, p.batch_at(5)["tokens"])
    q.close()


def test_token_distribution_structured():
    """Zipf + bigram mixing: heavy head, non-uniform successors."""
    cfg = DataConfig(vocab=1000, seq_len=256, global_batch=8)
    toks = TokenPipeline(cfg).batch_at(0)["tokens"].ravel()
    counts = np.bincount(toks, minlength=1000)
    top10 = counts[np.argsort(counts)[-10:]].sum()
    assert top10 > 0.2 * len(toks)          # zipfy head
    assert (counts > 0).sum() > 50          # but not degenerate
