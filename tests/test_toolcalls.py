"""Tool-call suspend/resume plane (ISSUE 10): tiered KV offload that
multiplies effective decode capacity.

Gates: the hold-open lifecycle (a tool-bound call parks SUSPENDED
instead of finishing, slot and pages returned), the eviction ladder
HBM -> host -> drop-and-recompute, resume-outranks-admission ordering,
the ``offload``/``host_capacity_pages`` knobs on the engine surface,
the OffloadPolicy / intent loop closed over the ``offload`` knob, the
ToolAgent heavy-tail + timeout model, and live-engine greedy-token
parity across suspend -> (same-engine resume | cross-engine migrate).
"""
import jax
import numpy as np
import pytest

from repro import models
from repro.agents.agent import ToolAgent, expected_tool_latency
from repro.configs import get_config
from repro.core import Controller, MetricBus, Registry, compile_intent
from repro.core.metrics import CentralPoller, Collector, StateStore
from repro.core.policies import OffloadPolicy
from repro.core.types import AgentCard, Message, Request, RequestState
from repro.serving.engine import Engine
from repro.serving.engine_sim import SimEngine
from repro.serving.scheduler import SchedulerConfig
from repro.sim.clock import EventLoop
from repro.sim.costmodel import CostModel


def _sim(max_slots=2, num_pages=256, host_pages=64, **kw):
    loop = EventLoop()
    cm = CostModel(get_config("agent-7b"), chips=4)
    cfg = SchedulerConfig(max_slots=max_slots, num_pages=num_pages,
                          host_capacity_pages=host_pages, **kw)
    return loop, SimEngine(loop, cm, cfg, collector=Collector())


def _held_call(prompt_len=64, max_new=4, est=2.0):
    """A request whose final token parks it for a tool (the stamp the
    workflow layer puts on calls that feed a TOOL stage)."""
    r = Request(prompt_len=prompt_len, max_new_tokens=max_new)
    r.meta["hold_open"] = True
    r.meta["tool_latency_est"] = est
    return r


# ---------------------------------------------------------------------------
# Sim engine: the hold-open lifecycle across the eviction ladder
# ---------------------------------------------------------------------------

def test_hold_open_suspends_to_host_and_warm_resumes():
    loop, eng = _sim()
    eng.set_param("offload", "aggressive")
    r = _held_call()
    calls_done = []
    eng.on_finish = lambda req, t: calls_done.append(t)
    eng.submit(r)
    loop.run_until(60.0)
    # the *call* completed (stage bookkeeping advanced) but the sequence
    # parked instead of dying — with zero HBM footprint and a free slot
    assert calls_done and r.generated == 4
    assert r.state == RequestState.SUSPENDED
    assert r.meta["suspend_tier"] == "host"
    assert eng.scheduler.num_running == 0
    assert eng.scheduler.suspended_seqs == 1
    assert eng.scheduler.alloc.host_pages > 0
    assert r.req_id in eng._host_store
    # a warm resume is priced as a host->HBM refill, not a recompute
    assert eng.restore_cost(r) == pytest.approx(
        eng.cm.restore_time(r.total_len))

    # tool returns: same sequence continues on the restored cache
    r.max_new_tokens += 2
    r.meta["post_tool_t0"] = loop.now()
    assert eng.resume_suspended(r) == "hit"
    loop.run_until(120.0)
    assert r.state == RequestState.FINISHED and r.generated == 6
    assert eng.scheduler.resume_hits == 1
    assert eng.scheduler.restore_hit_rate == 1.0
    assert eng.scheduler.alloc.host_pages == 0
    assert eng.restore_cost(r) == 0.0
    # post-tool TTFT was observed off the resume stamp
    assert len(eng.restore_ttfts) == 1 and eng.restore_ttfts[0] > 0


def test_auto_offload_pins_without_queue_pressure():
    """The ``auto`` rule: nobody wants the slot, so the parked sequence
    keeps it — offloading would pay the spill round trip for nothing."""
    loop, eng = _sim()
    assert eng.get_param("offload") == "auto"
    r = _held_call(prompt_len=32, max_new=3)
    eng.submit(r)
    loop.run_until(60.0)
    assert r.state == RequestState.SUSPENDED
    assert r.meta["suspend_tier"] == "pin"
    assert eng.scheduler.num_running == 1        # slot never left
    assert eng.scheduler.alloc.host_pages == 0
    assert eng.restore_cost(r) == 0.0            # nothing to refill
    r.max_new_tokens += 2
    assert eng.resume_suspended(r) == "pin"
    loop.run_until(120.0)
    assert r.state == RequestState.FINISHED and r.generated == 5


def test_wait_resume_outranks_fresh_admissions():
    """A returning tool call queued on the resume-pending list gets the
    freed slot *before* fresh work waiting in the admission queue."""
    loop, eng = _sim(max_slots=1, num_pages=64)
    eng.set_param("offload", "aggressive")
    r1 = _held_call()
    eng.submit(r1)
    loop.run_until(60.0)
    assert r1.state == RequestState.SUSPENDED

    r2 = Request(prompt_len=64, max_new_tokens=4)
    r3 = Request(prompt_len=64, max_new_tokens=4)
    eng.submit(r2)                               # takes the lone slot
    eng.submit(r3)                               # queues behind it
    r1.max_new_tokens += 2
    assert eng.resume_suspended(r1) == "wait"
    loop.run_until(400.0)
    for r in (r1, r2, r3):
        assert r.state == RequestState.FINISHED
    assert eng.scheduler.resume_hits == 1
    assert r1.finish_time <= r3.first_token_time


def test_host_tier_full_drops_and_recompute_resumes():
    """Bottom rung of the ladder: no host room at suspend time drops the
    KV; resume folds the generated tail into the prompt and re-prefills
    through normal admission."""
    loop, eng = _sim(host_pages=0)
    eng.set_param("offload", "aggressive")
    r = _held_call(prompt_len=48, max_new=4)
    eng.submit(r)
    loop.run_until(60.0)
    assert r.state == RequestState.SUSPENDED
    assert r.meta["suspend_tier"] == "drop"
    assert eng.scheduler.alloc.host_pages == 0
    assert r.req_id not in eng._host_store

    r.max_new_tokens += 2
    assert eng.resume_suspended(r) == "recompute"
    loop.run_until(200.0)
    assert r.state == RequestState.FINISHED
    assert eng.scheduler.resume_recomputes == 1
    assert eng.scheduler.restore_hit_rate == 0.0
    # the 4 generated tokens became prompt; the 2 new ones decoded on top
    assert r.prompt_len == 48 + 4
    assert r.generated == 2 and len(r.output_tokens) == 4 + 2


def test_finish_suspended_releases_parked_state():
    """The abandon path: a held-open sequence whose continuation went
    elsewhere frees its host copy and counts as finished."""
    loop, eng = _sim()
    eng.set_param("offload", "aggressive")
    r = _held_call()
    eng.submit(r)
    loop.run_until(60.0)
    assert r.state == RequestState.SUSPENDED
    assert eng.scheduler.alloc.host_pages > 0
    eng.finish_suspended(r)
    assert r.state == RequestState.FINISHED
    assert eng.scheduler.alloc.host_pages == 0
    assert eng.scheduler.suspended_seqs == 0
    assert r in eng.finished and r.req_id not in eng._host_store


def test_starved_pin_demotion_breaks_fanin_wedge():
    """The liveness rung under ``offload off``: queue pressure alone
    never evicts a pin (a parked tool call frees its own slot when the
    tool returns), but a *wedge* — every slot held by a pin whose tool
    cannot dispatch until queued sibling work runs — demotes the oldest
    blocked pin to the host tier so the siblings can make progress."""
    loop, eng = _sim()                           # 2 slots
    eng.set_param("offload", "off")
    a, b = _held_call(), _held_call()
    eng.submit(a)
    eng.submit(b)
    loop.run_until(60.0)
    assert a.meta["suspend_tier"] == "pin" == b.meta["suspend_tier"]
    assert eng.scheduler.num_running == 2

    f1 = Request(prompt_len=32, max_new_tokens=2)
    eng.submit(f1)                               # pressure, no wedge
    loop.run_until(90.0)
    assert eng.demote_count == 0 and f1.state == RequestState.QUEUED

    a.meta["tool_blocked"] = True                # one occupant blocked:
    f2 = Request(prompt_len=32, max_new_tokens=2)
    eng.submit(f2)                               # still no wedge — b's
    loop.run_until(120.0)                        # tool frees b's slot
    assert eng.demote_count == 0 and f1.state == RequestState.QUEUED

    b.meta["tool_blocked"] = True                # true wedge
    f3 = Request(prompt_len=32, max_new_tokens=2)
    eng.submit(f3)
    loop.run_until(180.0)
    assert eng.demote_count == 1
    assert a.meta["suspend_tier"] == "host"      # oldest pin spilled
    assert b.meta["suspend_tier"] == "pin"       # the rest stay pinned
    for f in (f1, f2, f3):
        assert f.state == RequestState.FINISHED
    # a demoted pin still resumes warm off the host tier
    a.max_new_tokens += 1
    a.meta.pop("tool_blocked")
    assert eng.resume_suspended(a) == "hit"
    loop.run_until(240.0)
    assert a.state == RequestState.FINISHED
    assert eng.scheduler.resume_hits == 1


# ---------------------------------------------------------------------------
# Knob surface
# ---------------------------------------------------------------------------

def test_suspend_knobs_on_engine_surface():
    loop, eng = _sim()
    assert eng.get_param("offload") == "auto"
    eng.set_param("offload", "aggressive")
    assert eng.offload == "aggressive"
    with pytest.raises(ValueError):
        eng.set_param("offload", "sometimes")
    # host capacity is a scheduler knob proxied through the engine; the
    # on_change hook resizes the allocator's host tier in place
    eng.set_param("host_capacity_pages", 8)
    assert eng.scheduler.cfg.host_capacity_pages == 8
    assert eng.scheduler.alloc.host_capacity_pages == 8
    card = eng.card()
    assert "offload" in card.knobs and "host_capacity_pages" in card.knobs
    assert "suspended_seqs" in card.metrics
    assert "restore_ttft" in card.metrics


# ---------------------------------------------------------------------------
# Control plane: OffloadPolicy + intent rule close the loop on the knob
# ---------------------------------------------------------------------------

def _control(objs, bus):
    loop = EventLoop()
    reg = Registry()
    for o in objs:
        reg.register(o)
    store = StateStore()
    poller = CentralPoller(store)
    c = Controller(loop, reg, poller, interval=0.05, bus=bus)
    col = Collector(bus=bus)
    poller.attach(col)
    return loop, reg, col, c


class FakeOffloadEngine:
    """Knob-surface stub: just the offload knob, for policy unit tests."""
    name, kind = "e0", "llm"

    def __init__(self):
        self.values = {"offload": "auto"}
        self._defaults = {}

    def card(self):
        return AgentCard(name=self.name, kind=self.kind,
                         knobs=dict(self.values),
                         metrics=("queue_len",), capabilities=())

    def get_param(self, k):
        return self.values[k]

    def set_param(self, k, v):
        self._defaults.setdefault(k, self.values[k])
        self.values[k] = v

    def reset_param(self, k):
        self.values[k] = self._defaults.get(k, self.values[k])


def test_offload_policy_escalates_and_relaxes():
    bus = MetricBus()
    eng = FakeOffloadEngine()
    loop, reg, col, c = _control([eng], bus)
    pol = OffloadPolicy("e0", queue_hi=8, queue_lo=2, dwell=0.0)
    c.install(pol)
    c.start()
    col.gauge("e0.queue_len", 12, 0.01)           # admission backed up
    loop.run_until(0.2)
    assert eng.values["offload"] == "aggressive"
    col.gauge("e0.queue_len", 1, 0.21)            # drained below low water
    loop.run_until(0.4)
    assert eng.values["offload"] == "auto"
    assert [w for _, w in pol.moves] == ["aggressive", "auto"]


def test_offload_policy_holds_between_watermarks():
    bus = MetricBus()
    eng = FakeOffloadEngine()
    loop, reg, col, c = _control([eng], bus)
    pol = OffloadPolicy("e0", queue_hi=8, queue_lo=2, dwell=0.0)
    c.install(pol)
    c.start()
    col.gauge("e0.queue_len", 5, 0.01)            # between the marks
    loop.run_until(0.2)
    assert eng.values["offload"] == "auto" and not pol.moves


def test_intent_rule_escalates_offload():
    bus = MetricBus()
    eng = FakeOffloadEngine()
    loop, reg, col, c = _control([eng], bus)
    c.install(compile_intent("""
rule offload on engine e0.queue_len > 8:
    => set engine e0.offload aggressive
"""))
    col.gauge("e0.queue_len", 4, 0.01)            # under threshold
    loop.run_until(0.05)
    assert eng.values["offload"] == "auto"
    col.gauge("e0.queue_len", 12, 0.06)           # breach
    loop.run_until(0.15)
    assert eng.values["offload"] == "aggressive"
    assert any(a.kind == "set" for a in c.action_log())


# ---------------------------------------------------------------------------
# ToolAgent: heavy-tailed latency + timeout/retry counters
# ---------------------------------------------------------------------------

def test_tool_timeout_and_retry_counters():
    loop = EventLoop()
    tool = ToolAgent("web", loop, latency=1.0, latency_cv=2.0,
                     timeout=1.5, max_retries=1, concurrency=4, seed=11)
    done = []
    for i in range(32):
        tool.deliver(Message(src="s", dst="web", payload=i),
                     on_done=done.append)
    loop.run_until(1e4)
    # every call completes (fail-open after the retry budget) ...
    assert len(done) == 32 and tool.calls == 32
    # ... but the cv=2 tail blew through the 1.5 s cap more than once
    assert tool.timeouts > 0 and tool.retries > 0
    assert tool.timeouts >= tool.retries
    # the planners charge the closed-form mean, not the nominal median
    assert tool.mean_latency() == pytest.approx(
        expected_tool_latency(1.0, 2.0, 1.5, 1))
    # tail math sanity: the lognormal mean dominates its median, and a
    # timeout caps (then retry-pads) the expectation below the raw mean
    assert expected_tool_latency(1.0, 2.0) == pytest.approx(5 ** 0.5)
    assert expected_tool_latency(1.0, 2.0, 1.5, 1) \
        < expected_tool_latency(1.0, 2.0)


# ---------------------------------------------------------------------------
# Live engine: suspend -> resume / migrate keeps greedy decode token-exact
# ---------------------------------------------------------------------------

BASE = get_config("tiny-agent").replace(dtype="float32")
PAGE = 16


def _live_engine(params, name):
    sched = SchedulerConfig(max_slots=2, num_pages=64, max_context=128,
                            page_size=PAGE, host_capacity_pages=32)
    return Engine(BASE, params, sched, name=name, cache_layout="paged")


def _ref_tokens(params, p, max_new):
    eng = _live_engine(params, "tc-ref")
    r = Request(prompt_len=len(p), max_new_tokens=max_new,
                prompt_tokens=np.asarray(p, np.int32))
    eng.submit(r)
    eng.run_until_idle()
    assert r.state == RequestState.FINISHED
    return list(r.output_tokens)


def _decode_partially(eng, p, max_new, upto):
    r = Request(prompt_len=len(p), max_new_tokens=max_new,
                prompt_tokens=np.asarray(p, np.int32))
    eng.submit(r)
    while r.generated < upto:
        eng.step()
    return r


def test_live_suspend_resume_preserves_greedy_decode():
    params = models.init(BASE, jax.random.key(0))
    p = np.arange(1, 28) % BASE.vocab
    ref = _ref_tokens(params, p, 10)

    # same-engine warm resume: spill to host, reclaim, decode on
    eng = _live_engine(params, "tc-home")
    r = _decode_partially(eng, p, 10, upto=4)
    assert eng.suspend_request(r, offload=True) == "host"
    assert eng.scheduler.num_running == 0
    assert eng.scheduler.alloc.is_suspended(r.req_id)
    assert eng.resume_suspended(r) == "hit"
    eng.run_until_idle()
    assert r.state == RequestState.FINISHED
    assert list(r.output_tokens) == ref

    # cross-engine migrate: the host copy lands on a sibling through the
    # handoff admission path and decoding continues token-exact there
    engA = _live_engine(params, "tc-src")
    engB = _live_engine(params, "tc-dst")
    r2 = _decode_partially(engA, p, 10, upto=4)
    assert engA.suspend_request(r2, offload=True) == "host"
    assert engA.migrate_suspended(r2, engB)
    assert not engA.scheduler.alloc.is_suspended(r2.req_id)
    assert r2.req_id not in engA._host_store
    engB.run_until_idle()
    assert r2.state == RequestState.FINISHED
    assert list(r2.output_tokens) == ref
    assert engB.scheduler.resume_hits == 1
