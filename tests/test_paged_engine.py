"""Live-engine paged-vs-ring equivalence + allocator churn.

The paged-pool KV layout (models/attention.py PagedKVCache) must be
token-exact with the ring-buffer oracle when the real Engine drives it
through live PageAllocator block tables — across GQA/MQA, sliding
windows, non-page-aligned contexts, the Pallas kernel path
(interpret mode on CPU), shared-prefix admission, preemption churn and
KV migration.  These are the CI gates for the measured fast path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import get_config
from repro.core.metrics import BUILTIN_SPECS, Collector, MetricBus
from repro.core.types import Request, RequestState
from repro.models import transformer as tfm
from repro.serving.engine import Engine
from repro.serving.kv_cache import PageAllocator, block_tables
from repro.serving.prefix_cache import PrefixCache
from repro.serving.scheduler import SchedulerConfig


BASE = get_config("tiny-agent").replace(dtype="float32")
PAGE = 16


def _params(cfg):
    return models.init(cfg, jax.random.key(0))


def _engine(cfg, params, layout, num_pages=64, max_slots=2, cache=False,
            name=None):
    sched = SchedulerConfig(max_slots=max_slots, num_pages=num_pages,
                            max_context=128, page_size=PAGE)
    name = name or f"pe-{layout}"
    eng = Engine(cfg, params, sched, name=name, cache_layout=layout)
    if cache:
        pc = PrefixCache(eng.scheduler.alloc, name=f"{name}.cache",
                         instance=name, block_tokens=PAGE, reserve_frac=0.8)
        eng.attach_cache(pc)
    return eng


def _run(eng, prompts, max_new=6):
    reqs = [Request(prompt_len=len(p), max_new_tokens=max_new,
                    prompt_tokens=np.asarray(p, np.int32)) for p in prompts]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    for r in reqs:
        assert r.state == RequestState.FINISHED
    return [r.output_tokens for r in reqs]


# ---------------------------------------------------------------------------
# Model-level parity: ring oracle vs paged gather vs Pallas kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_kv_heads", [2, 1], ids=["gqa", "mqa"])
@pytest.mark.parametrize("window", [-1, 24], ids=["full", "swa"])
def test_paged_model_logit_parity(n_kv_heads, window):
    """Non-page-aligned prompt, decode tail crossing a page boundary."""
    cfg = BASE.replace(n_kv_heads=n_kv_heads, window=window)
    params = _params(cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 27), 0, cfg.vocab)

    ring = models.init_cache(cfg, 2, 96)
    lr, ring = models.prefill(params, cfg, toks, ring)

    paged = models.init_cache(cfg, 2, 96, layout="paged", num_pages=16,
                              page_size=PAGE)
    pmax = 96 // PAGE
    tables = jnp.asarray([[b * pmax + j for j in range(pmax)]
                          for b in range(2)], jnp.int32)
    lps = []
    for b in range(2):
        lp, paged = tfm.prefill_paged(params, cfg, toks[b:b + 1], paged,
                                      tables[b:b + 1],
                                      jnp.zeros((1,), jnp.int32),
                                      jnp.int32(b))
        lps.append(lp)
    lp = jnp.concatenate(lps)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lp),
                               rtol=1e-4, atol=1e-4)

    cfgk = cfg.replace(use_pallas=True)
    tok_r = jnp.argmax(lr, -1)[:, None]
    tok_p = tok_r
    for _ in range(8):                 # crosses the 27->32 page boundary
        lr, ring = models.decode_step(params, cfg, tok_r, ring)
        lp, paged = models.decode_step(params, cfgk, tok_p, paged, tables)
        # kernel accumulates in a different order (lane padding + scale
        # compensation): logits agree loosely, argmax tokens exactly
        np.testing.assert_allclose(np.asarray(lr), np.asarray(lp),
                                   rtol=2e-3, atol=2e-3)
        tok_r = jnp.argmax(lr, -1)[:, None]
        tok_p = jnp.argmax(lp, -1)[:, None]
        assert (tok_r == tok_p).all()


# ---------------------------------------------------------------------------
# Live-engine token parity (the acceptance gate)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_kv_heads", [2, 1], ids=["gqa", "mqa"])
@pytest.mark.parametrize("window", [-1, 24], ids=["full", "swa"])
def test_live_engine_paged_vs_ring_tokens(n_kv_heads, window):
    cfg = BASE.replace(n_kv_heads=n_kv_heads, window=window)
    params = _params(cfg)
    prompts = [np.arange(5, 40) % cfg.vocab,      # 35: non-aligned
               np.arange(3, 30) % cfg.vocab]      # 27: < 2 pages
    ring = _run(_engine(cfg, params, "ring"), prompts)
    paged = _run(_engine(cfg, params, "paged"), prompts)
    kernel = _run(_engine(cfg.replace(use_pallas=True), params, "paged"),
                  prompts)
    assert ring == paged == kernel


def test_live_engine_executes_pallas_kernel(monkeypatch):
    """The acceptance criterion literally: Engine decode calls
    ops.paged_decode_attention with the allocator's live block table."""
    from repro.kernels import ops
    cfg = BASE.replace(use_pallas=True)
    params = _params(cfg)
    eng = _engine(cfg, params, "paged")
    calls = []
    real = ops.paged_decode_attention

    def spy(q, k_pages, v_pages, tables, ctx, **kw):
        # debug.callback delivers the *runtime* table values even though
        # the spy itself runs once at trace time inside the jitted step
        jax.debug.callback(lambda t: calls.append(np.asarray(t)), tables)
        return real(q, k_pages, v_pages, tables, ctx, **kw)

    monkeypatch.setattr(ops, "paged_decode_attention", spy)
    p = np.arange(4, 30) % cfg.vocab
    r = Request(prompt_len=len(p), max_new_tokens=3,
                prompt_tokens=np.asarray(p, np.int32))
    eng.submit(r)
    eng.step()                                   # prefill
    expect = eng.scheduler.alloc.page_table(r.req_id)
    eng.step()                                   # decode
    jax.effects_barrier()
    assert calls, "decode never reached the paged kernel"
    row = calls[-1][r.slot]
    assert list(row[:len(expect)]) == expect
    assert (row[len(expect):] == -1).all()


# ---------------------------------------------------------------------------
# Zero-copy shared prefixes
# ---------------------------------------------------------------------------

def test_shared_prefix_zero_copy_admission():
    cfg = BASE.replace(use_pallas=True)
    params = _params(cfg)
    eng = _engine(cfg, params, "paged", cache=True)
    shared = (np.arange(11, 43) % cfg.vocab).astype(np.int32)   # 2 pages
    pA = np.concatenate([shared, np.asarray([7, 8, 9], np.int32)])
    pB = np.concatenate([shared, np.asarray([1, 2, 3, 4], np.int32)])

    rA = Request(prompt_len=len(pA), max_new_tokens=5, prompt_tokens=pA)
    eng.submit(rA)
    eng.run_until_idle()
    prefix_ids = eng.scheduler.cache.chain(list(shared))
    shared_pages = [pid for blk in prefix_ids
                    for pid in eng.scheduler.alloc.block_pages(blk.digest)]
    assert len(shared_pages) == len(shared) // PAGE

    rB = Request(prompt_len=len(pB), max_new_tokens=5, prompt_tokens=pB)
    eng.submit(rB)
    eng.step()                     # admit + suffix prefill
    # the cached prefix was acquired by PHYSICAL ID — rB's table starts
    # with the exact pages rA's prefill wrote; nothing was copied
    assert rB.meta["cached_prompt_tokens"] == len(shared)
    assert eng.scheduler.alloc.page_table(rB.req_id)[:len(shared_pages)] \
        == shared_pages
    eng.run_until_idle()

    # oracle: same prompt, fresh engine with no cache
    out = _run(_engine(cfg, params, "paged", name="pe-oracle"), [pB],
               max_new=5)
    assert rB.output_tokens == out[0]


# ---------------------------------------------------------------------------
# Allocator churn: preempt / evict / reset keep pool + tables consistent
# ---------------------------------------------------------------------------

def _check_invariant(alloc: PageAllocator):
    assert alloc.free_pages + alloc.private_pages + alloc.shared_pages \
        == alloc.num_pages
    assert alloc.free_pages >= 0


def test_allocator_churn_keeps_tables_consistent():
    cfg = BASE
    params = _params(cfg)
    eng = _engine(cfg, params, "paged", num_pages=10, cache=True)
    alloc = eng.scheduler.alloc
    prompts = [np.arange(i, i + 30) % cfg.vocab for i in (2, 5, 9)]
    reqs = [Request(prompt_len=30, max_new_tokens=6,
                    prompt_tokens=np.asarray(p, np.int32)) for p in prompts]
    for r in reqs:
        eng.submit(r)
    eng.step()                                   # admit + prefill
    _check_invariant(alloc)

    # preempt the youngest running sequence mid-flight
    victim = eng.scheduler.preempt_one()
    assert victim is not None
    _check_invariant(alloc)
    assert alloc.page_table(victim.req_id) == []

    # evict an idle cache block if any, then drain everything
    eng.scheduler.cache.evict_one()
    _check_invariant(alloc)
    eng.run_until_idle()
    for r in reqs:
        assert r.state == RequestState.FINISHED
        assert len(r.output_tokens) == 6
    _check_invariant(alloc)

    # preempted victim restarted from scratch: tokens match the oracle
    oracle = _run(_engine(cfg, params, "paged", name="pe-churn-oracle"),
                  [victim.prompt_tokens])
    assert victim.output_tokens == oracle[0]

    eng.scheduler.cache.clear()
    alloc.reset()
    _check_invariant(alloc)
    assert alloc.free_pages == alloc.num_pages


def test_block_tables_fixed_width():
    alloc = PageAllocator(8, page_size=PAGE)
    assert alloc.allocate("s0", 3 * PAGE)
    rows = block_tables(alloc, ["s0"], width=5)
    assert len(rows[0]) == 5 and rows[0][3:] == [-1, -1]
    with pytest.raises(ValueError):
        block_tables(alloc, ["s0"], width=2)


# ---------------------------------------------------------------------------
# KV migration (paged extract -> paged insert)
# ---------------------------------------------------------------------------

def test_paged_migration_preserves_greedy_decode():
    cfg = BASE
    params = _params(cfg)
    engA = _engine(cfg, params, "paged", name="pe-src")
    engB = _engine(cfg, params, "paged", name="pe-dst")
    p = np.arange(1, 28) % cfg.vocab

    ref = _run(_engine(cfg, params, "paged", name="pe-ref"), [p],
               max_new=10)[0]

    r = Request(prompt_len=len(p), max_new_tokens=10,
                prompt_tokens=np.asarray(p, np.int32))
    engA.submit(r)
    while r.generated < 4:
        engA.step()
    state = engA.extract_state(r)
    first4 = list(r.output_tokens)
    engA.scheduler.preempt_one()
    r.generated = 4
    r.prefilled = r.prompt_len
    assert engB.scheduler.admit_direct(r)
    engB.inject_state(r, state)
    engB.run_until_idle()
    assert first4 + r.output_tokens == ref


# ---------------------------------------------------------------------------
# The cache_layout knob
# ---------------------------------------------------------------------------

def test_cache_layout_knob():
    cfg = BASE
    params = _params(cfg)
    eng = _engine(cfg, params, "ring")
    assert eng.get_param("cache_layout") == "ring"
    eng.set_param("cache_layout", "paged")
    assert eng.cache_layout == "paged"
    out = _run(eng, [np.arange(6, 30) % cfg.vocab])
    assert len(out[0]) == 6

    # flipping under live sequences must refuse and leave state intact
    r = Request(prompt_len=20, max_new_tokens=8,
                prompt_tokens=np.arange(20).astype(np.int32))
    eng.submit(r)
    eng.step()
    with pytest.raises(RuntimeError):
        eng.set_param("cache_layout", "ring")
    assert eng.cache_layout == "paged"
    eng.run_until_idle()

    # use_pallas defaults the layout to paged
    eng2 = Engine(cfg.replace(use_pallas=True), params,
                  SchedulerConfig(max_slots=1, num_pages=16,
                                  max_context=128, page_size=PAGE),
                  name="pe-default")
    assert eng2.cache_layout == "paged"


# ---------------------------------------------------------------------------
# mean_step_time rides the MetricBus (the hardware-honesty feedback loop)
# ---------------------------------------------------------------------------

def test_mean_step_time_published_on_bus():
    spec = BUILTIN_SPECS["mean_step_time"]
    assert spec.direction == "lower_better"

    bus = MetricBus()
    col = Collector("node0", bus=bus)
    fired = []
    bus.subscribe("pe-bus.mean_step_time",
                  lambda n, v, t: fired.append((n, v)),
                  above=0.0, edge=False)
    cfg = BASE
    eng = Engine(cfg, _params(cfg),
                 SchedulerConfig(max_slots=1, num_pages=16, max_context=128,
                                 page_size=PAGE),
                 name="pe-bus", collector=col, cache_layout="paged")
    _run(eng, [np.arange(4, 24) % cfg.vocab], max_new=3)
    assert fired and fired[-1][0] == "pe-bus.mean_step_time"
    assert fired[-1][1] == pytest.approx(eng.mean_step_time)
