"""Metrics plane: rings, aggregation, spec language, central polling."""
import math

from repro.core.metrics import (AGGREGATIONS, CentralPoller, Collector,
                                MetricBus, MetricSpec, Ring, StateStore,
                                register_aggregation)


def test_ring_wraps_and_windows():
    r = Ring(cap=4)
    for i in range(10):
        r.push(float(i), float(i))
    assert r.last() == 9.0
    w = r.window(since=7.0)
    assert [v for _, v in w] == [7.0, 8.0, 9.0]
    assert len(r.window()) == 4            # capacity bound


def test_aggregations():
    xs = [1.0, 2.0, 3.0, 4.0, 100.0]
    assert AGGREGATIONS["mean"](xs) == 22.0
    assert AGGREGATIONS["p50"](xs) == 3.0
    assert AGGREGATIONS["max"](xs) == 100.0
    assert AGGREGATIONS["count"](xs) == 5.0
    assert math.isnan(AGGREGATIONS["mean"]([]))


def test_custom_aggregation_registration():
    register_aggregation("range", lambda xs: max(xs) - min(xs) if xs else 0.0)
    assert AGGREGATIONS["range"]([3.0, 9.0]) == 6.0


def test_metric_spec_from_docstring():
    s = MetricSpec.from_docstring(
        "ttft", "Time to first token in seconds; lower is better.")
    assert s.kind == "latency"
    assert s.direction == "lower_better"
    assert s.unit == "seconds"
    assert s.default_agg == "p95"

    s2 = MetricSpec.from_docstring(
        "throughput", "Completed requests per second; higher is better.")
    assert s2.kind == "rate"
    assert s2.direction == "higher_better"

    s3 = MetricSpec.from_docstring(
        "tokens_total", "Cumulative number of generated tokens.")
    assert s3.kind == "counter"
    assert s3.default_agg == "sum"


def test_metric_spec_from_dict():
    s = MetricSpec.from_dict({"name": "queue_len", "kind": "gauge",
                              "direction": "lower_better"})
    assert s.direction == "lower_better"


def test_collector_and_poller_roundtrip():
    c = Collector("node0")
    store = StateStore()
    poller = CentralPoller(store, window=10.0)
    poller.attach(c)

    for t in range(5):
        c.gauge("eng.queue_len", t * 2, float(t))
        c.observe("eng.latency", 0.1 * t, float(t))
        c.counter("eng.msgs", 1, float(t))
    poller.poll(now=5.0)

    assert store.get("eng.queue_len", "last") == 8
    assert store.get("eng.queue_len", "mean") == 4.0
    assert abs(store.get("eng.latency", "max") - 0.4) < 1e-9
    assert store.get("eng.msgs", "last") == 5      # cumulative counter

    # windowed query: only samples newer than now-2
    assert store.get("eng.queue_len", "mean", window=2.0) == 7.0


def test_poll_window_excludes_stale():
    c = Collector()
    store = StateStore()
    poller = CentralPoller(store, window=1.0)
    poller.attach(c)
    c.gauge("m", 1.0, t=0.0)
    c.gauge("m", 2.0, t=9.5)
    poller.poll(now=10.0)
    assert store.get("m", "count") == 1.0          # only the fresh sample


def test_semantic_specs_attached_via_describe():
    c = Collector()
    c.describe("custom.depth",
               "Current depth of the compaction queue; lower is better.")
    spec = c.spec("custom.depth")
    assert spec.direction == "lower_better"
    # builtin fallback by suffix
    assert c.spec("tester-0.ttft").kind == "latency"


def test_glob_subscription_rearms_per_matched_series():
    """A glob threshold sub tracks each concrete series independently:
    one series sitting in-region must not mask (or re-arm) another's
    edge state."""
    bus = MetricBus()
    fired = []
    sub = bus.subscribe("eng-*.queue_len", above=5.0,
                        fn=lambda n, v, t: fired.append((n, v)))
    bus.publish("eng-a.queue_len", 6.0, 0.0)    # a enters -> fire
    bus.publish("eng-b.queue_len", 7.0, 1.0)    # b enters -> fire
    bus.publish("eng-a.queue_len", 7.0, 2.0)    # a still in-region: edge
    bus.publish("eng-a.queue_len", 3.0, 3.0)    # a leaves -> re-arms a only
    bus.publish("eng-b.queue_len", 8.0, 4.0)    # b never left: still edge
    bus.publish("eng-a.queue_len", 9.0, 5.0)    # a re-entered -> fire
    assert sub.fires == 3
    assert [n for n, _ in fired] == \
        ["eng-a.queue_len", "eng-b.queue_len", "eng-a.queue_len"]


def test_cooldown_suppression_keeps_subscription_armed():
    """Edge trigger and cooldown compose: a breach suppressed by the
    cooldown does NOT record region entry, so the same sustained breach
    fires once the cooldown expires rather than being lost."""
    bus = MetricBus()
    fired = []
    sub = bus.subscribe("m", above=5.0, cooldown=10.0,
                        fn=lambda n, v, t: fired.append(t))
    bus.publish("m", 6.0, 0.0)      # fire (records entry + last_fire)
    bus.publish("m", 7.0, 1.0)      # in-region: edge-blocked
    bus.publish("m", 3.0, 2.0)      # leaves region: re-arm
    bus.publish("m", 8.0, 3.0)      # re-entry but 3s < cooldown: suppressed,
    bus.publish("m", 8.0, 12.0)     # ... stayed ARMED -> fires post-cooldown
    assert sub.fires == 2
    assert fired == [0.0, 12.0]
