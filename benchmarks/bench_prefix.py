"""prefix — prefill-token and latency savings from the prefix-cache plane.

Scenario (the dominant agentic pattern): a parent "plan" turn establishes
a shared prompt prefix of L tokens; W worker turns then fan out, each
prompt = the L shared tokens + a small private suffix.  With the cache
off every worker re-prefills L from scratch; with it on, the prefix is
computed once and every worker's admission starts past it.

Sweeps fan-out width × shared-prefix length, cache on vs. off, and
reports charged prefill tokens, fan-out makespan, and the reductions —
the acceptance bar is ≥30% prefill-token reduction on the fan-out cells.
"""
from __future__ import annotations

from benchmarks.common import Report
from repro.configs import get_config
from repro.core.types import Request
from repro.serving.engine_sim import SimEngine
from repro.serving.prefix_cache import PrefixCache
from repro.serving.scheduler import SchedulerConfig
from repro.sim.clock import EventLoop
from repro.sim.costmodel import costmodel_for

FANOUTS = (4, 16, 64)
SHARED_LENS = (256, 1024, 4096)
SUFFIX = 64
GEN = 16


def run_cell(fanout: int, shared_len: int, enabled: bool) -> dict:
    loop = EventLoop()
    cm = costmodel_for(get_config("agent-7b"), chips=4)
    cfg = SchedulerConfig(max_slots=16, num_pages=4096, max_context=8192)
    eng = SimEngine(loop, cm, cfg, name="prefix-engine")
    if enabled:
        cache = PrefixCache(eng.scheduler.alloc, name="prefix-engine.cache",
                            instance="prefix-engine", block_tokens=64,
                            reserve_frac=0.8, clock=loop.now)
        eng.attach_cache(cache)

    def req(tag: str) -> Request:
        return Request(prompt_len=shared_len + SUFFIX, max_new_tokens=GEN,
                       meta={"prefix": (("task-context", shared_len),
                                        (f"worker:{tag}", SUFFIX))})

    # parent turn establishes the prefix
    parent = req("parent")
    eng.submit(parent)
    loop.run_until(1e4)
    assert parent.done

    # measured fan-out
    t0 = loop.now()
    workers = [req(str(i)) for i in range(fanout)]
    for r in workers:
        eng.submit(r)
    loop.run_until(t0 + 1e5)
    assert all(r.done for r in workers)

    prompt_total = sum(r.prompt_len for r in workers)
    cached = sum(r.meta.get("cached_prompt_tokens", 0) for r in workers)
    return {
        "prefill_tokens": prompt_total - cached,
        "prompt_tokens": prompt_total,
        "cached_tokens": cached,
        "makespan": max(r.finish_time for r in workers) - t0,
        "hit_rate": (eng.scheduler.cache.hit_rate
                     if eng.scheduler.cache else 0.0),
    }


def main(report: Report | None = None, smoke: bool = False) -> Report:
    rep = report or Report("prefix: fan-out x shared-prefix, cache on/off")
    fanouts = (8,) if smoke else FANOUTS
    shared_lens = (512,) if smoke else SHARED_LENS
    reductions = []
    for w in fanouts:
        for L in shared_lens:
            off = run_cell(w, L, enabled=False)
            on = run_cell(w, L, enabled=True)
            tok_red = 1.0 - on["prefill_tokens"] / max(off["prefill_tokens"],
                                                       1)
            lat_red = 1.0 - on["makespan"] / max(off["makespan"], 1e-12)
            reductions.append((w, L, tok_red, lat_red))
            rep.add(f"prefix.w{w}.L{L}",
                    prefill_off=off["prefill_tokens"],
                    prefill_on=on["prefill_tokens"],
                    tok_reduction=f"{tok_red:.3f}",
                    makespan_off=f"{off['makespan']:.3f}",
                    makespan_on=f"{on['makespan']:.3f}",
                    lat_reduction=f"{lat_red:.3f}",
                    hit_rate=f"{on['hit_rate']:.3f}")
    best = max(reductions, key=lambda r: r[2])
    mean_tok = sum(r[2] for r in reductions) / len(reductions)
    mean_lat = sum(r[3] for r in reductions) / len(reductions)
    rep.add("prefix.summary",
            mean_tok_reduction=f"{mean_tok:.3f}",
            mean_lat_reduction=f"{mean_lat:.3f}",
            best_cell=f"w{best[0]}xL{best[1]}",
            best_tok_reduction=f"{best[2]:.3f}",
            acceptance=">=0.30 tok reduction",
            passed=bool(mean_tok >= 0.30))
    rep.note(f"prefix: mean prefill-token reduction {mean_tok:.1%}, mean "
             f"fan-out makespan reduction {mean_lat:.1%} with the cache on "
             f"(acceptance: >=30% token reduction — "
             f"{'PASS' if mean_tok >= 0.30 else 'FAIL'})")
    return rep


if __name__ == "__main__":
    print(main().render())
