"""Fig 6 — adaptive communication control under shifting load.

The workload moves through phases (low → high → low).  Static
granularities are each optimal in one phase only; the controller's
AdaptiveGranularityPolicy observes tester load and switches the channel
at runtime, converging to the best mechanism per phase (the paper's
Fig 6 demonstration).
"""
from __future__ import annotations

import statistics

from benchmarks.common import Report, pctl
from repro.agents import AgenticPipeline, PipelineConfig, WorkloadConfig
from repro.agents.workloads import Phase, PhasedLoad
from repro.core.policies import AdaptiveGranularityPolicy
from repro.core.types import Granularity

PHASES = [Phase(25.0, 2), Phase(25.0, 64), Phase(25.0, 2)]
HORIZON = sum(p.duration for p in PHASES)


def run_mode(mode: str):
    p = AgenticPipeline(PipelineConfig(
        granularity=Granularity.PIPELINE if mode == "adaptive"
        else Granularity(mode),
        n_testers=1, stream_chunk=1))
    pol = None
    if mode == "adaptive":
        pol = AdaptiveGranularityPolicy("dev->tester", ["tester-0"],
                                        stream_below=3.0, batch_above=20.0)
        p.controller.install(pol)
    load = PhasedLoad(p, WorkloadConfig(think_time=0.3), PHASES)
    load.start()
    p.run(until=HORIZON + 10.0)

    # per-phase completion counts
    per_phase = []
    t = 0.0
    for ph in PHASES:
        n = sum(1 for s in p.done if t <= s.finished_at < t + ph.duration)
        per_phase.append(n / ph.duration)
        t += ph.duration
    lats = p.latencies()
    return {
        "per_phase": per_phase,
        "total": len(p.done),
        "mean_lat": statistics.mean(lats) if lats else float("nan"),
        "p95_lat": pctl(lats, 0.95),
        "switches": [(round(t, 1), g.value) for t, g in pol.switches]
        if pol else [],
    }


def main(report: Report | None = None) -> Report:
    rep = report or Report("fig6: adaptive granularity under shifting load")
    results = {}
    for mode in ("batch", "pipeline", "stream", "adaptive"):
        r = run_mode(mode)
        results[mode] = r
        rep.add(f"fig6.{mode}",
                phase_thpt="/".join(f"{x:.2f}" for x in r["per_phase"]),
                total=r["total"],
                mean_lat=f"{r['mean_lat']:.3f}",
                p95_lat=f"{r['p95_lat']:.3f}")
    ad = results["adaptive"]
    rep.add("fig6.switching", events=";".join(
        f"{t}s->{g}" for t, g in ad["switches"]) or "none")

    # convergence check: adaptive within tolerance of the best static
    # config in every phase
    ok = True
    for i in range(len(PHASES)):
        best_static = max(results[m]["per_phase"][i]
                          for m in ("batch", "pipeline", "stream"))
        if ad["per_phase"][i] < 0.85 * best_static:
            ok = False
    best_total = max(results[m]["total"]
                     for m in ("batch", "pipeline", "stream"))
    rep.add("fig6.summary",
            adaptive_total=ad["total"],
            best_static_total=best_total,
            adaptive_tracks_best_static_per_phase=ok)
    rep.note("fig6: the controller switches mechanism as load shifts and "
             f"tracks the per-phase best static config (ok={ok}); no "
             "static config is best in all phases")
    return rep


if __name__ == "__main__":
    print(main().render())
