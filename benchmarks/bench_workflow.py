"""Workflow graph plane benchmark: does exposing the DAG to the serving
layer pay?

Sweeps the three non-fig1 topology families (fan-out width x chain
depth) under three serving arms with an EQUAL chip budget:

* ``static``       — the pre-graph posture: session-hash routing, FIFO
  within priority, one model tier.  The serving layer sees requests,
  not the workflow.
* ``critical_path``— the graph is a control-plane object: per-stage
  deadlines propagated along edges (EDF within priority + longest-
  remaining-path tie-break + behind-schedule admission boost), least-
  loaded routing.  Same single tier.
* ``stage_aware``  — critical_path + Aragog-style per-stage model
  tiering: cheap stages (map workers, mid-chain reviewers, debate
  sides) carry ``model_tier="small"`` and the ``stage_aware`` router
  keeps their calls on the small-model instances, freeing the large
  tier for critical-path stages.

Acceptance (ISSUE 3): critical-path + stage-aware beats static by >=15%
on makespan or p95 task latency on at least two of the three shapes.

    PYTHONPATH=src python benchmarks/bench_workflow.py [--smoke]
"""
from __future__ import annotations

import sys
from pathlib import Path

# runnable both as `python -m benchmarks.run --only workflow` and
# directly as `python benchmarks/bench_workflow.py`
_ROOT = Path(__file__).resolve().parent.parent
for p in (str(_ROOT), str(_ROOT / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

from benchmarks.common import Report, pctl  # noqa: E402
from repro.agents import (AgenticPipeline, TierSpec, WorkflowConfig,
                          debate, deep_review, map_reduce)  # noqa: E402
from repro.agents.workloads import GraphBurst  # noqa: E402

# 12-chip budget per arm: 3x4-chip large engines, or 2x4-chip large
# plus 4x1-chip small when the pool is tiered
ARMS = {
    "static": dict(
        tiers={"large": TierSpec("agent-7b", chips=4, replicas=3, slots=16)},
        router_policy="static", critical_path=False),
    "critical_path": dict(
        tiers={"large": TierSpec("agent-7b", chips=4, replicas=3, slots=16)},
        router_policy="least_loaded", critical_path=True),
    "stage_aware": dict(
        tiers={"large": TierSpec("agent-7b", chips=4, replicas=2, slots=16),
               "small": TierSpec("agent-1b", chips=1, replicas=4, slots=16)},
        router_policy="stage_aware", critical_path=True),
}


def shapes(smoke: bool):
    """(label, family, graph builder) — cheap stages are tiered small;
    arms without a small pool in their tier map serve them on the
    default tier, so the graphs are identical across arms."""
    widths = (4,) if smoke else (4, 8)
    depths = (4,) if smoke else (4, 8)
    out = []
    for w in widths:
        out.append((f"map_reduce/w{w}", "map_reduce",
                    lambda w=w: map_reduce(width=w, worker_tier="small")))
    for d in depths:
        out.append((f"deep_review/d{d}", "deep_review",
                    lambda d=d: deep_review(depth=d, reviewer_tier="small")))
    out.append(("debate", "debate", lambda: debate(side_tier="small")))
    return out


def run_arm(build_graph, arm: dict, n_tasks: int):
    wp = AgenticPipeline.build(build_graph(), WorkflowConfig(**arm))
    burst = GraphBurst(wp, n_tasks, prompt_tokens=128, stagger=0.05)
    burst.start()
    wp.run(until=600.0)
    assert len(wp.done) == n_tasks, (len(wp.done), n_tasks)
    lats = wp.latencies()
    makespan = (max(t.finished_at for t in wp.done)
                - min(t.submitted_at for t in wp.done))
    return {"makespan": makespan, "p95": pctl(lats, 0.95),
            "mean": sum(lats) / len(lats),
            "tier_routed": wp.router.tier_routed}


def main(smoke: bool = False):
    report = Report("workflow graph plane: static vs critical-path vs "
                    "stage-aware (equal 12-chip budget)")
    n_tasks = 8 if smoke else 16
    wins = {}
    for label, family, build in shapes(smoke):
        res = {arm: run_arm(build, cfg, n_tasks)
               for arm, cfg in ARMS.items()}
        base = res["static"]
        for arm in ("static", "critical_path", "stage_aware"):
            r = res[arm]
            report.add(f"{label}/{arm}",
                       makespan_s=round(r["makespan"], 3),
                       p95_s=round(r["p95"], 3),
                       mean_s=round(r["mean"], 3),
                       tier_routed=r["tier_routed"],
                       makespan_gain_pct=round(
                           100 * (1 - r["makespan"] / base["makespan"]), 1),
                       p95_gain_pct=round(
                           100 * (1 - r["p95"] / base["p95"]), 1))
        sa = res["stage_aware"]
        gain = max(1 - sa["makespan"] / base["makespan"],
                   1 - sa["p95"] / base["p95"])
        wins.setdefault(family, 0.0)
        wins[family] = max(wins[family], gain)
    passing = [f for f, g in wins.items() if g >= 0.15]
    report.note(f"best stage_aware gain per shape family: "
                + ", ".join(f"{f}={g*100:.1f}%" for f, g in wins.items()))
    report.note(f"acceptance (>=15% on >=2 of 3 shapes): "
                f"{'PASS' if len(passing) >= 2 else 'FAIL'} "
                f"({len(passing)}/3: {passing})")
    return report


if __name__ == "__main__":
    rep = main(smoke="--smoke" in sys.argv)
    print(rep.render())
