"""Shared benchmark utilities: workload builders + reporting."""
from __future__ import annotations

from dataclasses import dataclass, field


def pctl(xs, q):
    if not xs:
        return float("nan")
    s = sorted(xs)
    k = max(0, min(len(s) - 1, int(round(q * (len(s) - 1)))))
    return s[k]


@dataclass
class Row:
    name: str
    fields: dict

    def csv(self) -> str:
        vals = ",".join(f"{k}={v}" for k, v in self.fields.items())
        return f"{self.name},{vals}"


class Report:
    def __init__(self, title: str):
        self.title = title
        self.rows: list[Row] = []
        self.notes: list[str] = []

    def add(self, name: str, **fields):
        self.rows.append(Row(name, fields))

    def note(self, text: str):
        self.notes.append(text)

    def render(self) -> str:
        out = [f"== {self.title} =="]
        out += [r.csv() for r in self.rows]
        out += [f"# {n}" for n in self.notes]
        return "\n".join(out)

    def to_dict(self) -> dict:
        """JSON-shaped summary so the perf trajectory is trackable
        across PRs (benchmarks/run.py writes BENCH_<section>.json)."""
        return {
            "title": self.title,
            "rows": [{"name": r.name, **r.fields} for r in self.rows],
            "notes": list(self.notes),
        }
