"""Tenancy plane benchmark: does making *who is served next* a runtime
knob pay under multi-tenant agentic traffic?

Two arms at an EQUAL chip budget (2 engines x 4 chips), differing in a
single knob — the scheduler queue ``discipline``:

* ``fifo_priority``  — the classic order (priority, EDF, FIFO): one
  noisy tenant's flood sits ahead of everyone who arrived later, which
  is exactly the statically-encoded serving attribute the paper argues
  against;
* ``weighted_fair``  — start-time virtual-time fairness over tenants
  (weights from the ``TenantDirectory``): the gold tenant's small
  interactive requests sort ahead of the flood because its
  served-tokens-per-weight lags, while priority/EDF still orders work
  *within* each tenant.

Three traffic shapes, measuring the gold tenant's p95 TTFT (the SLO
under attack) and the fleet's aggregate decode throughput (fairness
must not cost delivered output — same criterion as bench_disagg):

* ``noisy_neighbor`` — a gold tenant's closed-loop interactive sessions
  vs one batch tenant's open-loop flood of long prompts;
* ``flash_crowd``    — a standard tenant's rate spikes 10x mid-run;
* ``mixed_slo``      — gold + standard + batch tenants on a heavy-head
  rate split, all at once.

Acceptance (ISSUE 5): weighted_fair improves gold-tenant p95 TTFT by
>=30% vs fifo_priority on >=2 of 3 shapes AND aggregate decode
throughput never drops more than 5% below fifo_priority on any shape.

    PYTHONPATH=src python benchmarks/bench_tenancy.py [--smoke]
"""
from __future__ import annotations

import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
for p in (str(_ROOT), str(_ROOT / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

from benchmarks.common import Report, pctl  # noqa: E402
from repro.agents.workloads import TenantLoad, TenantMix  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.core.metrics import Collector, MetricBus  # noqa: E402
from repro.core.registry import Registry  # noqa: E402
from repro.core.tenancy import TenantDirectory, TenantSpec  # noqa: E402
from repro.serving.disagg import DisaggPool  # noqa: E402
from repro.serving.engine_sim import SimEngine  # noqa: E402
from repro.serving.kv_transfer import (KVTransferManager,  # noqa: E402
                                       SessionDirectory)
from repro.serving.scheduler import SchedulerConfig  # noqa: E402
from repro.sim.clock import EventLoop  # noqa: E402
from repro.sim.costmodel import costmodel_for  # noqa: E402

N_ENGINES = 2
CHIPS_PER_ENGINE = 4                  # 8-chip budget per arm
SLOTS = 8
ARMS = ("fifo_priority", "weighted_fair")


class _Fleet:
    """One arm: engines + DisaggPool + tenant directory."""

    def __init__(self, discipline: str, specs: list[TenantSpec]):
        self.loop = EventLoop()
        self.bus = MetricBus()
        self.collector = Collector("bench", bus=self.bus)
        self.registry = Registry()
        self.tenants = TenantDirectory(collector=self.collector,
                                       registry=self.registry)
        for spec in specs:
            self.tenants.add(spec)
        cm = costmodel_for(get_config("agent-7b"), chips=CHIPS_PER_ENGINE)
        self.engines = []
        for i in range(N_ENGINES):
            eng = SimEngine(
                self.loop, cm,
                SchedulerConfig(max_slots=SLOTS, num_pages=4096,
                                max_context=4096, max_batch_tokens=2048,
                                prefill_chunk=512),
                name=f"e{i}", collector=self.collector)
            # the arm differs in ONE knob, set through the Table-1
            # surface like any controller would
            eng.set_param("discipline", discipline)
            self.engines.append(eng)
            self.registry.register(eng)
        kvx = KVTransferManager(self.loop, SessionDirectory(),
                                bytes_fn=cm.kv_transfer_bytes,
                                collector=self.collector)
        self.pool = DisaggPool(self.loop, self.engines, kvx,
                               collector=self.collector,
                               tenants=self.tenants)


def _shape_loads(shape: str, smoke: bool):
    """(tenant specs, loads, mid-run mutator) per traffic shape."""
    gold_spec = TenantSpec("gold", weight=4.0, slo_class="gold",
                           p95_ttft_target=0.5)
    if shape == "noisy_neighbor":
        specs = [gold_spec, TenantSpec("noisy", weight=0.5,
                                       slo_class="batch")]
        loads = [
            TenantLoad("gold", slo_class="gold", mode="closed", sessions=6,
                       think=0.05, prompt=128, gen=96),
            TenantLoad("noisy", slo_class="batch", mode="open",
                       rate=(40.0 if smoke else 60.0),
                       prompt=1024, gen=64),
        ]
        return specs, loads, None
    if shape == "flash_crowd":
        specs = [gold_spec, TenantSpec("crowd", weight=1.0)]
        crowd = TenantLoad("crowd", mode="open", rate=6.0,
                           prompt=768, gen=48)
        loads = [
            TenantLoad("gold", slo_class="gold", mode="closed", sessions=6,
                       think=0.05, prompt=128, gen=96),
            crowd,
        ]

        def mutate(loop, horizon):
            # 10x spike through the middle third of the run
            loop.call_at(horizon * 0.3,
                         lambda: setattr(crowd, "rate", 60.0))
            loop.call_at(horizon * 0.6,
                         lambda: setattr(crowd, "rate", 6.0))
        return specs, loads, mutate
    if shape == "mixed_slo":
        specs = [
            gold_spec,
            TenantSpec("std-0", weight=1.0),
            TenantSpec("std-1", weight=1.0),
            TenantSpec("batch-0", weight=0.25, slo_class="batch"),
            TenantSpec("batch-1", weight=0.25, slo_class="batch"),
        ]
        scale = 0.75 if smoke else 1.0
        loads = [
            TenantLoad("gold", slo_class="gold", mode="closed", sessions=4,
                       think=0.05, prompt=128, gen=96),
            TenantLoad("std-0", mode="open", rate=16.0 * scale,
                       prompt=512, gen=32),
            TenantLoad("std-1", mode="open", rate=8.0 * scale,
                       prompt=512, gen=32),
            TenantLoad("batch-0", slo_class="batch", mode="open",
                       rate=48.0 * scale, prompt=1024, gen=48),
            TenantLoad("batch-1", slo_class="batch", mode="open",
                       rate=24.0 * scale, prompt=1024, gen=48),
        ]
        return specs, loads, None
    raise ValueError(shape)


def run_arm(arm: str, shape: str, smoke: bool) -> dict:
    horizon = 8.0 if smoke else 20.0
    specs, loads, mutate = _shape_loads(shape, smoke)
    fleet = _Fleet(arm, specs)
    mix = TenantMix(fleet.loop, fleet.pool.submit, loads,
                    t_end=horizon, seed=0)
    TenantMix.wire_pool(fleet.pool)
    if mutate is not None:
        mutate(fleet.loop, horizon)
    mix.start()
    fleet.loop.run_until(horizon)
    now = fleet.loop.now()

    def ttfts(tenant: str) -> list[float]:
        out = []
        for r in mix.requests[tenant]:
            if r.first_token_time is not None:
                out.append(r.first_token_time - r.arrival_time)
            else:
                out.append(now - r.arrival_time)   # censored: still waiting
        return out

    served = {t: fleet.tenants.get(t).served_tokens
              for t in fleet.tenants.names()}
    total_served = sum(served.values())
    gold = ttfts("gold")
    decode_tokens = sum(e.tokens_generated for e in fleet.engines)
    return {
        "gold_p95_ttft": pctl(gold, 0.95),
        "gold_mean_ttft": sum(gold) / max(len(gold), 1),
        "gold_requests": len(gold),
        "gold_share": served.get("gold", 0.0) / max(total_served, 1.0),
        "decode_tok_s": decode_tokens / horizon,
        "served_tok_s": total_served / horizon,
        "requests": sum(len(v) for v in mix.requests.values()),
        "preemptions": sum(e.scheduler.preempt_count
                           for e in fleet.engines),
    }


def main(smoke: bool = False):
    report = Report("tenancy plane: fifo_priority vs weighted_fair "
                    "(equal 8-chip budget)")
    shapes = ("noisy_neighbor", "flash_crowd", "mixed_slo")
    gains, tput_ok = [], []
    for shape in shapes:
        res = {arm: run_arm(arm, shape, smoke) for arm in ARMS}
        base = res["fifo_priority"]
        for arm in ARMS:
            r = res[arm]
            report.add(
                f"{shape}/{arm}",
                gold_p95_ttft_s=round(r["gold_p95_ttft"], 4),
                gold_mean_ttft_s=round(r["gold_mean_ttft"], 4),
                gold_share_pct=round(100 * r["gold_share"], 1),
                decode_tok_s=round(r["decode_tok_s"], 0),
                served_tok_s=round(r["served_tok_s"], 0),
                requests=r["requests"],
                gold_requests=r["gold_requests"],
                ttft_gain_pct=round(
                    100 * (1 - r["gold_p95_ttft"] / base["gold_p95_ttft"]),
                    1),
                tput_vs_fifo_pct=round(
                    100 * (r["decode_tok_s"] / base["decode_tok_s"] - 1), 1))
        wf = res["weighted_fair"]
        gain = 1 - wf["gold_p95_ttft"] / base["gold_p95_ttft"]
        keeps = wf["decode_tok_s"] >= 0.95 * base["decode_tok_s"]
        gains.append((shape, gain))
        tput_ok.append((shape, keeps))
    passing = [s for s, g in gains if g >= 0.30]
    report.note("weighted_fair gold p95-TTFT gain vs fifo_priority: "
                + ", ".join(f"{s}={g*100:.1f}%" for s, g in gains))
    report.note("aggregate throughput no worse than 5% below "
                "fifo_priority: "
                + ", ".join(f"{s}={'yes' if k else 'NO'}"
                            for s, k in tput_ok))
    ok = len(passing) >= 2 and all(k for _, k in tput_ok)
    report.note(f"acceptance (>=30% gold p95-TTFT on >=2/3 shapes, "
                f"aggregate tput no worse than -5%): "
                f"{'PASS' if ok else 'FAIL'} "
                f"({len(passing)}/3 TTFT: {passing})")
    return report


if __name__ == "__main__":
    rep = main(smoke="--smoke" in sys.argv)
    print(rep.render())
