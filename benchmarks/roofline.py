"""Roofline analysis: render §Dry-run + §Roofline tables from the
artifacts launch/dryrun.py wrote.

Three terms per (arch × shape), single-pod mesh, TPU v5e constants:

    compute    = HLO_FLOPs        / (chips × 197e12 FLOP/s)
    memory     = HLO_bytes        / (chips × 819e9  B/s)
    collective = collective_bytes / (chips × 2 links × 50e9 B/s)

HLO totals come from the *unrolled* cost pass (XLA counts while-loop
bodies once — see dryrun.py); cost_analysis totals are per-partition
already, so the `chips` division applies to the collective term only
(its byte count is summed over the whole module's collective ops).
"""
from __future__ import annotations

import json
from pathlib import Path

PEAK = 197e12          # bf16 FLOP/s/chip
HBM = 819e9            # B/s/chip
ICI = 50e9             # B/s/link
LINKS = 2              # effective links/chip for ring collectives

ART = Path("artifacts/dryrun")


def load(mesh: str = "single") -> list[dict]:
    recs = []
    for f in sorted(ART.glob(f"*.{mesh}.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def terms(rec: dict) -> dict | None:
    if "flops" not in rec:
        return None
    chips = rec["chips"]
    t_c = rec["flops"] / PEAK                       # per-partition FLOPs
    t_m = rec["bytes_accessed"] / HBM
    t_x = rec["collectives"]["total"] / (chips * LINKS * ICI)
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    useful = rec["model_flops"] / max(rec["flops"] * chips, 1.0)
    return {"compute": t_c, "memory": t_m, "collective": t_x,
            "dominant": dom, "useful": useful,
            "bound": max(t_c, t_m, t_x),
            "frac": (rec["model_flops"] / chips / PEAK)
            / max(t_c, t_m, t_x, 1e-12)}


SUGGEST = {
    "compute": "compute-bound: fuse/reduce non-matmul FLOPs "
               "(remat policy, cheaper recompute), or grow per-chip batch",
    "memory": "HBM-bound: cut bytes/step — fuse elementwise chains, "
              "bigger per-step batch to amortize weight reads, quantize "
              "weights/KV",
    "collective": "collective-bound: reshard to cut resharding traffic "
                  "(kv-head TP cap, seq-sharding), or overlap collectives "
                  "with compute (latency-hiding schedule)",
}


def render(mesh: str = "single") -> str:
    rows = []
    head = ("| arch | shape | compute s | memory s | collective s | "
            "dominant | MODEL/HLO | roofline frac |")
    sep = "|" + "---|" * 8
    rows += [head, sep]
    for rec in load(mesh):
        tag = f"| {rec['arch']} | {rec['shape']} "
        if "skipped" in rec:
            rows.append(tag + "| — | — | — | skipped | — | — |")
            continue
        if "error" in rec:
            rows.append(tag + "| — | — | — | ERROR | — | — |")
            continue
        t = terms(rec)
        if t is None:
            continue
        rows.append(
            tag + f"| {t['compute']:.3e} | {t['memory']:.3e} "
            f"| {t['collective']:.3e} | {t['dominant']} "
            f"| {t['useful']:.2f} | {t['frac']:.1%} |")
    return "\n".join(rows)


def render_memory(mesh: str) -> str:
    rows = ["| arch | shape | mesh | peak GB/dev | args GB | temps GB | "
            "compile s |", "|" + "---|" * 7]
    for rec in load(mesh):
        if "memory" not in rec:
            continue
        m = rec["memory"]
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
            f"| {m['peak_bytes']/1e9:.2f} | {m['argument_bytes']/1e9:.2f} "
            f"| {m['temp_bytes']/1e9:.2f} | {rec['compile_s']} |")
    return "\n".join(rows)


def main():
    print("== roofline (single-pod, 256 chips) ==")
    print(render("single"))
    print()
    print("== memory / compile (single-pod) ==")
    print(render_memory("single"))
    print()
    print("== multi-pod sharding proof (512 chips) ==")
    print(render_memory("multi"))
    # per-cell suggestion lines
    print()
    for rec in load("single"):
        t = terms(rec)
        if t:
            print(f"# {rec['arch']}.{rec['shape']}: {t['dominant']}-bound "
                  f"-> {SUGGEST[t['dominant']]}")


if __name__ == "__main__":
    main()
