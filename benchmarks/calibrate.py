"""CostModel calibration harness: fit the roofline to *measured* steps.

    PYTHONPATH=src python -m benchmarks.calibrate [--smoke]
                                                  [--out-dir artifacts/bench]

Two things happen per run:

1. **Correctness** (always, interpret mode): the paged decode-attention
   kernel is checked against the ref.py gather-then-attend oracle through
   a shared-prefix block table with non-page-aligned context lengths, and
   the contiguous decode kernel at a non-block-divisible T (the tail-
   truncation regression).
2. **Measurement + fit** (wherever a JAX backend exists — on the CPU
   container this times XLA-CPU, on TPU the real thing): the jitted
   ``models.prefill`` / ``models.decode_step`` functions — the exact
   executables serving/engine.py dispatches — are timed across a
   (batch × context × model-config) grid; sim/calibration.py least-
   squares-fits ``flops_scale`` / ``bytes_scale`` / ``step_overhead``
   and the result is persisted as ``CALIB_<model>.json`` for
   ``CostModel.from_calibration``.

Decode cost depends on the cache's ``max_context`` (the ring is a fixed
shape: every step reads/masks the whole ring), so the decode grid varies
``init_cache``'s max_context — that IS the resident-context axis.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from benchmarks.common import Report
from repro import models
from repro.configs.base import ModelConfig
from repro.kernels import ops, ref
from repro.sim.calibration import (CalibrationPoint, calibrate,
                                   save_calibration)
from repro.sim.costmodel import CostModel

TINY = ModelConfig(name="calib-tiny", family="dense", n_layers=2,
                   d_model=128, n_heads=4, n_kv_heads=2, d_ff=256, vocab=512)
SMALL = ModelConfig(name="calib-small", family="dense", n_layers=4,
                    d_model=256, n_heads=8, n_kv_heads=4, d_ff=512,
                    vocab=1024)

PREFILL_LENS = (64, 128, 256, 512)
DECODE_GRID = ((1, 128), (1, 512), (2, 256), (4, 512), (8, 1024))
PREFILL_LENS_SMOKE = (64, 128)
DECODE_GRID_SMOKE = ((1, 128), (2, 256), (4, 256))


def _time_step(fn, *args, reps: int = 5) -> float:
    out = fn(*args)                      # compile + warm
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


# ---------------------------------------------------------------------------
# Interpret-mode correctness (runs everywhere, no accelerator needed)
# ---------------------------------------------------------------------------
def kernel_correctness(rep: Report) -> None:
    # paged decode-attention through a shared-prefix block table with
    # non-page-aligned context lengths — the allocator-shaped case
    page, hkv, g, dh = 16, 2, 2, 64
    b, per_seq = 3, 6
    h = hkv * g
    n = 2 + b * (per_seq - 2)            # 2 shared + private pages
    ks = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(ks[0], (b, 1, h, dh), jnp.float32)
    k_pages = jax.random.normal(ks[1], (n, page, hkv, dh), jnp.float32)
    v_pages = jax.random.normal(ks[2], (n, page, hkv, dh), jnp.float32)
    rows, nxt = [], 2
    for _ in range(b):                   # same physical prefix ids per row
        rows.append([0, 1] + list(range(nxt, nxt + per_seq - 2)))
        nxt += per_seq - 2
    bt = jnp.asarray(rows, jnp.int32)
    ctx = jnp.asarray([page * per_seq, page * per_seq - 5, 2 * page + 3],
                      jnp.int32)
    out = ops.paged_decode_attention(q, k_pages, v_pages, bt, ctx,
                                     interpret=True)
    want = ref.paged_decode_attention_ref(q.reshape(b, hkv, g, dh),
                                          k_pages, v_pages, bt, ctx)
    err = float(jnp.abs(out.reshape(b, hkv, g, dh) - want).max())
    rep.add("calibrate.correctness.paged_decode_attention",
            max_err=f"{err:.2e}", ok=err < 1e-4)

    # contiguous decode kernel at non-block-divisible T (tail regression)
    t = 200
    ks = jax.random.split(jax.random.key(8), 3)
    q = jax.random.normal(ks[0], (2, 1, h, dh), jnp.float32)
    ck = jax.random.normal(ks[1], (2, t, hkv, dh), jnp.float32)
    cv = jax.random.normal(ks[2], (2, t, hkv, dh), jnp.float32)
    kpos = jnp.broadcast_to(jnp.arange(t)[None], (2, t))
    qp = jnp.full((2,), t - 1)
    out = ops.decode_attention(q, ck, cv, kpos, qp, interpret=True)
    want = ref.decode_attention_ref(q.reshape(2, hkv, g, dh),
                                    jnp.moveaxis(ck, 2, 1),
                                    jnp.moveaxis(cv, 2, 1), kpos, qp[:, None])
    err = float(jnp.abs(out.reshape(2, hkv, g, dh) - want).max())
    rep.add("calibrate.correctness.decode_attention_tail",
            max_err=f"{err:.2e}", ok=err < 1e-4, t=t)


# ---------------------------------------------------------------------------
# Measurement grid
# ---------------------------------------------------------------------------
def measure_points(cfg: ModelConfig, prefill_lens, decode_grid,
                   reps: int = 5) -> list[CalibrationPoint]:
    params = models.init(cfg, jax.random.key(0))
    cm = CostModel(cfg, chips=1)
    pts: list[CalibrationPoint] = []
    for length in prefill_lens:
        cache = models.init_cache(cfg, 1, length)
        tokens = jnp.zeros((1, length), jnp.int32)
        fn = jax.jit(lambda p, t, c, _cfg=cfg: models.prefill(p, _cfg, t, c))
        t = _time_step(fn, params, tokens, cache, reps=reps)
        flops, bytes_ = cm.prefill_cost(length)
        pts.append(CalibrationPoint("prefill", 1, length, flops, bytes_, t))
    for batch, ctx in decode_grid:
        cache = models.init_cache(cfg, batch, ctx)
        tokens = jnp.zeros((batch, 1), jnp.int32)
        fn = jax.jit(
            lambda p, t, c, _cfg=cfg: models.decode_step(p, _cfg, t, c))
        t = _time_step(fn, params, tokens, cache, reps=reps)
        flops, bytes_ = cm.decode_cost(batch, ctx)
        pts.append(CalibrationPoint("decode", batch, ctx, flops, bytes_, t))
    return pts


def calibrate_config(cfg: ModelConfig, out_dir: Path, rep: Report,
                     smoke: bool = False) -> Path:
    """Measure, fit, persist and report one model config.  Returns the
    CALIB artifact path."""
    lens = PREFILL_LENS_SMOKE if smoke else PREFILL_LENS
    grid = DECODE_GRID_SMOKE if smoke else DECODE_GRID
    backend = jax.default_backend()
    pts = measure_points(cfg, lens, grid, reps=3 if smoke else 5)
    calib = calibrate(cfg.name, backend, pts, chips=1)
    path = save_calibration(calib, Path(out_dir) / f"CALIB_{cfg.name}.json")
    for p, err in zip(calib.points, calib.rel_errors()):
        rep.add(f"calibrate.{cfg.name}.{p.kind}.b{p.batch}c{p.context}",
                measured_us=f"{p.measured_s*1e6:.1f}",
                predicted_us=f"{calib.predict(p)*1e6:.1f}",
                rel_err=f"{err:.3f}")
    rep.add(f"calibrate.{cfg.name}.fit",
            backend=backend,
            flops_scale=f"{calib.flops_scale:.3g}",
            bytes_scale=f"{calib.bytes_scale:.3g}",
            step_overhead_us=f"{calib.step_overhead*1e6:.1f}",
            max_rel_err=f"{calib.max_rel_err:.3f}",
            tolerance=calib.tolerance,
            within_tolerance=calib.within_tolerance,
            artifact=str(path))
    return path


def main(smoke: bool = False, out_dir: str = "artifacts/bench",
         report: Report | None = None) -> Report:
    rep = report or Report("calibrate: measured roofline fit")
    kernel_correctness(rep)
    for cfg in ([TINY] if smoke else [TINY, SMALL]):
        calibrate_config(cfg, Path(out_dir), rep, smoke=smoke)
    rep.note(f"backend={jax.default_backend()}: on the CPU container the "
             "fit absorbs XLA-CPU throughput into flops/bytes scales; on "
             "TPU the same harness calibrates against real step times")
    rep.note("CALIB_<model>.json feeds CostModel.from_calibration — the "
             "sim plane's step times then come from measurement, not "
             "hand-set constants")
    return rep


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out-dir", default="artifacts/bench")
    a = ap.parse_args()
    print(main(smoke=a.smoke, out_dir=a.out_dir).render())
