"""Fig 3 — serving throughput/latency of the developer→tester pipeline
under three *static* communication granularities across load levels.

Paper claim: no single configuration wins everywhere; a suboptimal
static choice costs up to 3.6×.  We sweep closed-loop concurrency
(sessions) and report tasks/s + latency per granularity, then the
worst-case degradation ratio observed.
"""
from __future__ import annotations

import statistics

from benchmarks.common import Report, pctl
from repro.agents import AgenticPipeline, PipelineConfig, WorkloadConfig
from repro.agents.workloads import launch_clients
from repro.core.types import Granularity

LOADS = (1, 4, 16, 64, 96)
SMOKE_LOADS = (1, 16)
WARMUP, HORIZON = 10.0, 70.0
SMOKE_HORIZON = 25.0
GRANS = (Granularity.BATCH, Granularity.PIPELINE, Granularity.STREAM)


def run_cell(gran: Granularity, n_clients: int, stream_chunk: int = 1,
             horizon: float = HORIZON):
    p = AgenticPipeline(PipelineConfig(
        granularity=gran, n_testers=1, stream_chunk=stream_chunk))
    launch_clients(p, WorkloadConfig(n_clients=n_clients, think_time=0.3),
                   stop_at=horizon - 10.0)
    p.run(until=horizon)
    lats = p.latencies()
    return {
        "throughput": p.throughput(WARMUP, horizon),
        "mean_lat": statistics.mean(lats) if lats else float("nan"),
        "p95_lat": pctl(lats, 0.95),
        "msgs": p.channel.msgs_sent,
    }


def main(report: Report | None = None, smoke: bool = False) -> Report:
    rep = report or Report("fig3: granularity x load (static configs)")
    loads = SMOKE_LOADS if smoke else LOADS
    horizon = SMOKE_HORIZON if smoke else HORIZON
    table: dict[int, dict[Granularity, dict]] = {}
    for n in loads:
        table[n] = {}
        for g in GRANS:
            r = run_cell(g, n, horizon=horizon)
            table[n][g] = r
            rep.add(f"fig3.load{n}.{g.value}",
                    thpt=f"{r['throughput']:.3f}",
                    mean_lat=f"{r['mean_lat']:.3f}",
                    p95_lat=f"{r['p95_lat']:.3f}",
                    msgs=r["msgs"])

    # paper-claim summary: best/worst ratios at the extremes
    ratios = []
    for n in loads:
        best = max(table[n].values(), key=lambda r: r["throughput"])
        worst = min(table[n].values(), key=lambda r: r["throughput"])
        if worst["throughput"] > 0:
            ratios.append((n, best["throughput"] / worst["throughput"]))
    spread = max(r for _, r in ratios)
    # which granularity wins, per load level
    winners = {n: max(table[n], key=lambda g: table[n][g]["throughput"])
               .value for n in loads}
    lat_winners = {n: min(table[n],
                          key=lambda g: table[n][g]["mean_lat"]).value
                   for n in loads}
    rep.add("fig3.summary",
            max_degradation=f"{spread:.2f}x",
            paper_claim="3.6x",
            thpt_winner_by_load=str(winners).replace(",", ";"),
            lat_winner_by_load=str(lat_winners).replace(",", ";"))
    crossover = len(set(winners.values()) | set(lat_winners.values())) > 1
    rep.note(f"fig3: crossover reproduced={crossover} — no single "
             f"granularity wins all loads; worst static choice costs "
             f"{spread:.2f}x (paper: up to 3.6x)")
    return rep


if __name__ == "__main__":
    print(main().render())
