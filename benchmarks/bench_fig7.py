"""Fig 7 — controller-driven load balancing with KV-cache transfer.

One developer, two tester instances.  Session→instance static hashing is
adversarially skewed (75% of sessions land on tester-0), arrivals are
open-loop Poisson near the two-instance capacity, so without control the
hot instance builds queue while the other idles.

Three arms, as in the paper:
  * none      — static hashing, no balancing (baseline),
  * reactive  — controller re-pins sessions to the least-loaded
                instance; the destination pulls session KV only when the
                request arrives (transfer on the critical path),
  * hints     — the controller pre-positions the KV at task_start,
                overlapping the transfer with the developer's generation.

Primary metric: goodput (tasks completing within the SLO), as the
paper's control objective balances user experience with throughput.
Paper ratios: hints ≈ 1.8× over reactive-after-arrival; controller LB
≈ 2.3× over no balancing.
"""
from __future__ import annotations

import statistics

from benchmarks.common import Report, pctl
from repro.agents import AgenticPipeline, PipelineConfig, WorkloadConfig
from repro.agents.workloads import OpenLoopSource
from repro.core.policies import LoadBalancePolicy
from repro.core.types import Granularity

# crc32(name) % 2 == 0 -> tester-0 (precomputed; router uses crc32)
HOT = ["sess-4", "sess-5", "sess-6", "sess-7", "sess-14", "sess-15",
       "sess-16", "sess-17", "sess-20", "sess-21", "sess-26", "sess-27"]
COLD = ["sess-0", "sess-1", "sess-9", "sess-11"]

RATE = 0.55             # tasks/s/session -> ~8.8 tasks/s offered
T_END = 60.0
HORIZON = 100.0
SLO = 3.0               # seconds end-to-end per task


def run_mode(mode: str):
    p = AgenticPipeline(PipelineConfig(
        granularity=Granularity.PIPELINE, n_testers=2,
        router_policy="static", dev_chips=8, tester_chips=2,
        kv_bandwidth=3.125e9))
    pol = LoadBalancePolicy([t.name for t in p.testers], mode=mode,
                            imbalance_min=4.0, cooldown=4.0)
    p.controller.install(pol)
    src = OpenLoopSource(p, HOT + COLD, RATE,
                         WorkloadConfig(n_functions=6, func_tokens=48,
                                        test_tokens=40),
                         t_end=T_END)
    src.start()
    p.run(until=HORIZON)
    lats = p.latencies()
    good = sum(1 for s in p.done
               if (s.finished_at - s.submitted_at) <= SLO)
    kvw = [w for t in p.testers for w in t.kv_waits]
    stalls = [w for w in kvw if w > 0]
    stall_per_handoff = (sum(stalls) / max(pol.migrations, 1)
                         if pol.migrations else 0.0)
    return {
        "offered": src.submitted / T_END,
        "completed": len(p.done),
        "goodput": good / T_END,
        "mean_lat": statistics.mean(lats) if lats else float("nan"),
        "p95_lat": pctl(lats, 0.95),
        "migrations": pol.migrations,
        "transfers": p.kvx.transfers,
        "kv_wait_mean": statistics.mean(kvw) if kvw else 0.0,
        "handoff_stall": stall_per_handoff,
        "stalled_handoffs": len(stalls),
        "gb_moved": p.kvx.bytes_moved / 1e9,
    }


def main(report: Report | None = None) -> Report:
    rep = report or Report("fig7: load balancing + KV transfer hints")
    res = {}
    for mode in ("none", "reactive", "hints"):
        r = res[mode] = run_mode(mode)
        rep.add(f"fig7.{mode}",
                offered=f"{r['offered']:.2f}",
                goodput=f"{r['goodput']:.2f}",
                completed=r["completed"],
                mean_lat=f"{r['mean_lat']:.2f}",
                p95_lat=f"{r['p95_lat']:.2f}",
                migrations=r["migrations"],
                handoff_stall=f"{r['handoff_stall']:.3f}",
                stalled=r["stalled_handoffs"],
                gb_moved=f"{r['gb_moved']:.1f}")
    lb_gain = res["hints"]["goodput"] / max(res["none"]["goodput"], 1e-9)
    stall_gain = (res["reactive"]["handoff_stall"]
                  / max(res["hints"]["handoff_stall"], 1e-9))
    hint_lat = (res["reactive"]["p95_lat"]
                / max(res["hints"]["p95_lat"], 1e-9))
    rep.add("fig7.summary",
            lb_vs_none=f"{lb_gain:.2f}x", paper_lb="2.3x",
            hints_vs_reactive_handoff_stall=f"{stall_gain:.2f}x",
            hints_vs_reactive_p95=f"{hint_lat:.2f}x",
            paper_hints="1.8x")
    rep.note(f"fig7: controller LB {lb_gain:.2f}x goodput over no "
             f"balancing (paper 2.3x); proactive hints cut the per-"
             f"hand-off KV stall {stall_gain:.2f}x vs reactive transfer "
             f"(paper reports 1.8x end-to-end on a GPU prototype whose "
             f"reactive path also stalls the engine; our virtual-clock "
             f"engines keep serving while a transfer is in flight, so "
             f"the aggregate-latency effect is smaller)")
    return rep


if __name__ == "__main__":
    print(main().render())
