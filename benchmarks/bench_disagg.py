"""Disaggregation plane benchmark: does making *engine role* a runtime
knob pay under bursty agentic traffic?

Three fleets at an EQUAL chip budget (4 engines x 4 chips), three
traffic shapes, measuring the two numbers the disaggregation literature
argues about:

* **p95 TTFT** — fan-out prefill bursts from the workflow plane queue
  behind long-lived decode sequences on unified engines (slots held by
  decoders block admission; prefill steps and decode steps contend for
  the same step loop);
* **decode throughput** — tokens/s the fleet sustains for the
  latency-sensitive decode streams while bursts land.

Arms:

* ``unified``       — every engine runs the classic prefill+decode loop
  (the pre-disagg posture); routing by shallowest prefill queue.
* ``static_disagg`` — a fixed 1-prefill / 3-decode split wired through
  the DisaggPool's chunk-streamed KV handoff fabric.
* ``adaptive_role`` — same starting split plus a ``RoleBalancerPolicy``
  flipping roles at runtime from the fleet's ``cluster.*`` gauges (the
  software-defined arm: role assignment follows queue pressure).

Acceptance (ISSUE 4): adaptive_role beats unified on p95 TTFT by >=15%
on >=2 of the 3 shapes AND keeps decode throughput within 5% of
unified on every shape.

    PYTHONPATH=src python benchmarks/bench_disagg.py [--smoke]
"""
from __future__ import annotations

import random
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
for p in (str(_ROOT), str(_ROOT / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

from benchmarks.common import Report, pctl  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.core.controller import Controller  # noqa: E402
from repro.core.metrics import (CentralPoller, Collector, MetricBus,  # noqa: E402
                                StateStore)
from repro.core.policies import RoleBalancerPolicy  # noqa: E402
from repro.core.registry import Registry  # noqa: E402
from repro.core.types import Priority, Request  # noqa: E402
from repro.serving.disagg import DisaggPool  # noqa: E402
from repro.serving.engine_sim import SimEngine  # noqa: E402
from repro.serving.kv_transfer import (KVTransferManager,  # noqa: E402
                                       SessionDirectory)
from repro.serving.scheduler import SchedulerConfig  # noqa: E402
from repro.sim.clock import EventLoop  # noqa: E402
from repro.sim.costmodel import costmodel_for  # noqa: E402

N_ENGINES = 4
CHIPS_PER_ENGINE = 4                  # 16-chip budget per arm
PHYSICAL_SLOTS = 16                   # hardware batch ceiling per engine
# Role-coupled batch shape: a unified (prefill-capable) engine reserves
# activation memory for 2048-token prefill chunks, capping its decode
# batch; a decode-only engine spends that headroom on extra decode
# slots.  The RoleBalancerPolicy co-flips max_num_seqs with the role,
# so the fleet's decode capacity follows the partition at runtime.
SLOT_PROFILE = {"unified": 12, "prefill": PHYSICAL_SLOTS,
                "decode": PHYSICAL_SLOTS}
ROLE_SPLITS = {
    "unified": ("unified",) * N_ENGINES,
    "static_disagg": ("prefill", "decode", "decode", "decode"),
    "adaptive_role": ("prefill", "decode", "decode", "decode"),
}


class _Fleet:
    """One arm: engines + DisaggPool + control plane."""

    def __init__(self, roles, adaptive: bool):
        self.loop = EventLoop()
        self.bus = MetricBus()
        self.collector = Collector("bench", bus=self.bus)
        self.store = StateStore()
        self.poller = CentralPoller(self.store)
        self.poller.attach(self.collector)
        self.registry = Registry()
        self.controller = Controller(self.loop, self.registry, self.poller,
                                     interval=0.05, bus=self.bus)
        cm = costmodel_for(get_config("agent-7b"), chips=CHIPS_PER_ENGINE)
        self.engines = []
        for i, role in enumerate(roles):
            eng = SimEngine(
                self.loop, cm,
                SchedulerConfig(max_slots=PHYSICAL_SLOTS, num_pages=4096,
                                max_context=4096, max_batch_tokens=2048,
                                prefill_chunk=512, role=role),
                name=f"e{i}", collector=self.collector)
            eng.set_param("max_num_seqs", SLOT_PROFILE[role])
            self.engines.append(eng)
            self.registry.register(eng)
        directory = SessionDirectory()
        kvx = KVTransferManager(self.loop, directory,
                                bytes_fn=cm.kv_transfer_bytes,
                                collector=self.collector)
        self.pool = DisaggPool(self.loop, self.engines, kvx,
                               collector=self.collector)
        if adaptive:
            self.controller.install(RoleBalancerPolicy(
                [e.name for e in self.engines],
                pressure_hi=1.0, pressure_lo=0.1,
                min_prefill=1, min_decode=1, dwell=1.25,
                release_dwell=0.25, window=1.0,
                slot_profile=SLOT_PROFILE))
        self.reqs: list[Request] = []

    def submit(self, prompt: int, gen: int, session: str,
               priority: Priority = Priority.NORMAL) -> Request:
        r = Request(prompt_len=prompt, max_new_tokens=gen,
                    priority=priority)
        self.reqs.append(r)
        self.pool.submit(r, session=session)
        return r


class _DecodeSession:
    """Closed-loop chat session: long decode streams that keep slots
    occupied (the latency-sensitive traffic bursts interfere with)."""

    def __init__(self, fleet: _Fleet, name: str, prompt: int, gen: int,
                 think: float, rng: random.Random, stop_at: float):
        self.f = fleet
        self.name = name
        self.prompt, self.gen = prompt, gen
        self.think, self.rng, self.stop_at = think, rng, stop_at

    def start(self, delay: float) -> None:
        self.f.loop.call_after(delay, self._fire)

    def _fire(self) -> None:
        if self.f.loop.now() >= self.stop_at:
            return
        # interactive decode streams outrank background fan-out bursts
        # (same priority split in every arm)
        r = self.f.submit(self.prompt, self.gen, self.name,
                          priority=Priority.HIGH)
        r.meta["on_done"] = self._done

    def _done(self) -> None:
        self.f.loop.call_after(
            self.think * (1 + self.rng.uniform(-0.3, 0.3)), self._fire)


def _drive(fleet: _Fleet, shape: str, horizon: float, smoke: bool) -> None:
    rng = random.Random(0)
    n_sessions = 52 if smoke else 56
    chat = dict(prompt=128, gen=224, think=0.05)
    burst_every, burst_k = 2.0, (20 if smoke else 24)

    def dispatch_done(req: Request, t: float) -> None:
        cb = req.meta.get("on_done")
        if cb is not None:
            cb()
    fleet.pool.on_finish = dispatch_done

    def start_sessions(n, stop_at=horizon):
        for i in range(n):
            s = _DecodeSession(fleet, f"chat-{i}", chat["prompt"],
                               chat["gen"], chat["think"], rng, stop_at)
            s.start(delay=rng.uniform(0, 0.5))

    def burst(k, prompt=768, gen=8):
        for i in range(k):
            fleet.submit(prompt, gen, f"burst-{fleet.loop.now():.1f}-{i}")

    if shape == "bursty_fanout":
        # steady chat floor + periodic wide fan-out prefill bursts
        start_sessions(n_sessions)
        t = 1.0
        while t < horizon:
            fleet.loop.call_at(t, lambda k=burst_k: burst(k))
            t += burst_every
    elif shape == "steady_mix":
        # open-loop Poisson mix: mostly prefill-heavy agentic calls over
        # a decode floor — no bursts, pure sustained contention
        start_sessions(int(n_sessions * 0.7))
        t, rate = 0.5, (10.0 if smoke else 16.0)
        while t < horizon:
            fleet.loop.call_at(t, lambda: burst(1, prompt=1024, gen=8))
            t += rng.expovariate(rate)
    elif shape == "phase_shift":
        # prefill-heavy first half, decode-heavy second half: the shape
        # static splits cannot be right for on both sides
        t = 0.5
        while t < horizon * 0.5:
            fleet.loop.call_at(t, lambda k=burst_k: burst(k))
            t += burst_every * 0.75
        fleet.loop.call_at(horizon * 0.45,
                           lambda: start_sessions(n_sessions))
    else:
        raise ValueError(shape)


def run_arm(arm: str, shape: str, smoke: bool) -> dict:
    horizon = 10.0 if smoke else 20.0
    fleet = _Fleet(ROLE_SPLITS[arm], adaptive=(arm == "adaptive_role"))
    _drive(fleet, shape, horizon, smoke)
    fleet.controller.start()
    fleet.loop.run_until(horizon)
    now = fleet.loop.now()
    ttfts = []
    for r in fleet.reqs:
        if r.first_token_time is not None:
            ttfts.append(r.first_token_time - r.arrival_time)
        else:
            ttfts.append(now - r.arrival_time)   # censored: still waiting
    decode_tokens = sum(e.tokens_generated for e in fleet.engines)
    return {
        "p95_ttft": pctl(ttfts, 0.95),
        "mean_ttft": sum(ttfts) / max(len(ttfts), 1),
        "decode_tput": decode_tokens / horizon,
        "requests": len(fleet.reqs),
        "handoffs": fleet.pool.handoffs,
        "migrations": fleet.pool.migrations,
        "role_flips": sum(len(p.flips) for p in fleet.controller.policies
                          if isinstance(p, RoleBalancerPolicy)),
    }


def main(smoke: bool = False):
    report = Report("disaggregation plane: unified vs static-disagg vs "
                    "adaptive-role (equal 16-chip budget)")
    shapes = ("bursty_fanout", "steady_mix", "phase_shift")
    ttft_wins, tput_ok = [], []
    for shape in shapes:
        res = {arm: run_arm(arm, shape, smoke) for arm in ROLE_SPLITS}
        base = res["unified"]
        for arm in ROLE_SPLITS:
            r = res[arm]
            report.add(
                f"{shape}/{arm}",
                p95_ttft_s=round(r["p95_ttft"], 4),
                mean_ttft_s=round(r["mean_ttft"], 4),
                decode_tok_s=round(r["decode_tput"], 1),
                requests=r["requests"],
                handoffs=r["handoffs"],
                role_flips=r["role_flips"],
                ttft_gain_pct=round(
                    100 * (1 - r["p95_ttft"] / base["p95_ttft"]), 1),
                tput_vs_unified_pct=round(
                    100 * (r["decode_tput"] / base["decode_tput"] - 1), 1))
        ad = res["adaptive_role"]
        gain = 1 - ad["p95_ttft"] / base["p95_ttft"]
        keeps = ad["decode_tput"] >= 0.95 * base["decode_tput"]
        ttft_wins.append((shape, gain))
        tput_ok.append((shape, keeps))
    passing = [s for s, g in ttft_wins if g >= 0.15]
    report.note("adaptive p95-TTFT gain vs unified: "
                + ", ".join(f"{s}={g*100:.1f}%" for s, g in ttft_wins))
    report.note("decode throughput within 5% of unified: "
                + ", ".join(f"{s}={'yes' if k else 'NO'}"
                            for s, k in tput_ok))
    ok = len(passing) >= 2 and all(k for _, k in tput_ok)
    report.note(f"acceptance (>=15% p95-TTFT on >=2/3 shapes, decode "
                f"tput within 5%): {'PASS' if ok else 'FAIL'} "
                f"({len(passing)}/3 TTFT: {passing})")
    return report


if __name__ == "__main__":
    rep = main(smoke="--smoke" in sys.argv)
    print(rep.render())
