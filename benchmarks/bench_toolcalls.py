"""Tool-call suspend/resume benchmark: does tiered KV offload multiply
effective decode capacity?

Agentic workflows spend seconds-long stretches waiting on tools
(search, code execution, retrieval) with heavy-tailed latency.  The
pre-ISSUE-10 posture — ``pin`` — keeps the tool-waiting sequence in its
decode slot for the whole dwell, so a handful of outstanding tool calls
can park an engine's entire slot budget.  The ``suspend`` arm spills
the sequence's private KV pages to the host tier (shared prefix blocks
stay refcounted in HBM), returns the slot immediately, and restores on
tool completion through cache-aware placement — the same context
continues token-exact, priced by the CostModel's host-bandwidth
roofline.

Two tool-heavy shapes (debate's fan-in factcheck, deep_review's
per-reviewer research chain), heavy-tailed 1-10 s tools, EQUAL chip
budget per arm.

Acceptance (ISSUE 10): suspend/resume >= 40% goodput gain over
pin-the-slot on each shape, with p95 post-tool TTFT <= 1.5x the
never-suspended (pinned) baseline.

    PYTHONPATH=src python benchmarks/bench_toolcalls.py [--smoke]
"""
from __future__ import annotations

import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
for p in (str(_ROOT), str(_ROOT / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

from benchmarks.common import Report, pctl  # noqa: E402
from repro.agents import (AgenticPipeline, TierSpec, WorkflowConfig,
                          debate, deep_review)  # noqa: E402
from repro.agents.workloads import GraphBurst  # noqa: E402

# 8-chip budget per arm: 2x4-chip engines.  Slots are deliberately
# scarce relative to outstanding tool calls — the regime the paper's
# tool-call plane targets (capacity bound by parked sequences, not
# FLOPs).
ARMS = {
    "pin": "off",              # baseline: tool dwell holds the slot
    "suspend": "aggressive",   # spill every tool wait to the host tier
}


def _tiers():
    return {"large": TierSpec("agent-7b", chips=4, replicas=2, slots=2)}


def shapes(smoke: bool):
    """(label, graph builder, stagger) — medians 2-4 s, cv=1 lognormal
    tails reaching past 10 s, capped by the tool timeout.  Stagger is
    tuned per shape so decode demand and tool dwell genuinely contend
    for slots (a synchronized wave would let the pin arm park for free
    while the queues are empty)."""
    out = [("debate/tool4s", lambda: debate(
        tool_latency=4.0, tool_latency_cv=1.0, tool_timeout=12.0), 1.0)]
    if not smoke:
        out.append(("deep_review/d4/tool2s", lambda: deep_review(
            depth=4, tool_latency=2.0, tool_latency_cv=1.0,
            tool_timeout=10.0), 1.0))
    return out


def run_arm(build_graph, offload: str, n_tasks: int, stagger: float):
    wp = AgenticPipeline.build(build_graph(), WorkflowConfig(
        tiers=_tiers(), router_policy="least_loaded", critical_path=True))
    for w in wp.workers:
        w.engine.set_param("offload", offload)
    for st in wp.stages.values():
        if st.tool is not None:
            # external tools (search APIs, sandboxes) are wide: the
            # contended resource under test is decode capacity, not the
            # tool endpoint's own concurrency limit
            st.tool.set_param("concurrency", 64)
    burst = GraphBurst(wp, n_tasks, prompt_tokens=128, stagger=stagger)
    burst.start()
    wp.run(until=3000.0)
    assert len(wp.done) == n_tasks, (offload, len(wp.done), n_tasks)
    lats = wp.latencies()
    makespan = (max(t.finished_at for t in wp.done)
                - min(t.submitted_at for t in wp.done))
    engines = [w.engine for w in wp.workers]
    ttfts = [x for e in engines for x in e.restore_ttfts]
    hits = sum(e.scheduler.resume_hits for e in engines)
    recomputes = sum(e.scheduler.resume_recomputes for e in engines)
    return {
        "goodput": n_tasks / makespan,
        "makespan": makespan,
        "p95": pctl(lats, 0.95),
        "post_tool_ttft_p95": pctl(ttfts, 0.95) if ttfts else 0.0,
        "suspends": sum(e.suspend_count for e in engines),
        "resume_hits": hits,
        "resume_recomputes": recomputes,
        "hit_rate": hits / (hits + recomputes) if hits + recomputes else 1.0,
    }


def main(smoke: bool = False):
    report = Report("tool-call plane: pin-the-slot vs suspend/resume "
                    "(equal 8-chip budget, heavy-tail 1-10 s tools)")
    n_tasks = 16 if smoke else 24
    verdicts = []
    for label, build, stagger in shapes(smoke):
        res = {arm: run_arm(build, offload, n_tasks, stagger)
               for arm, offload in ARMS.items()}
        base = res["pin"]
        for arm in ARMS:
            r = res[arm]
            report.add(f"{label}/{arm}",
                       goodput_tps=round(r["goodput"], 4),
                       makespan_s=round(r["makespan"], 2),
                       p95_s=round(r["p95"], 2),
                       post_tool_ttft_p95_s=round(
                           r["post_tool_ttft_p95"], 4),
                       suspends=r["suspends"],
                       resume_hits=r["resume_hits"],
                       resume_recomputes=r["resume_recomputes"],
                       hit_rate=round(r["hit_rate"], 3),
                       goodput_gain_pct=round(
                           100 * (r["goodput"] / base["goodput"] - 1), 1))
        sus = res["suspend"]
        gain = sus["goodput"] / base["goodput"] - 1
        # floor the pinned baseline at 50 ms: a pinned resume is nearly
        # instant, and sub-perceptual differences in that regime would
        # make the 1.5x ratio pure noise — the gate is about not making
        # users *notice* the restore after a tool returns
        ratio = (sus["post_tool_ttft_p95"]
                 / max(base["post_tool_ttft_p95"], 0.05))
        ok = gain >= 0.40 and ratio <= 1.5
        verdicts.append(ok)
        report.note(f"{label}: goodput gain {gain * 100:.1f}% "
                    f"(gate >=40%), post-tool TTFT p95 ratio "
                    f"{ratio:.2f}x pinned (gate <=1.5x) -> "
                    f"{'PASS' if ok else 'FAIL'}")
        if not ok:
            report.note(f"WARNING: {label} below the suspend/resume "
                        "acceptance gate")
    report.note("acceptance (every shape >=40% goodput gain at <=1.5x "
                f"post-tool TTFT): "
                f"{'PASS' if all(verdicts) else 'FAIL'} "
                f"({sum(verdicts)}/{len(verdicts)} shapes)")
    return report


if __name__ == "__main__":
    rep = main(smoke="--smoke" in sys.argv)
    print(rep.render())
