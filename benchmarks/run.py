"""Benchmark orchestrator — one section per paper figure/table plus the
kernel microbench and (if dry-run artifacts exist) the roofline tables.

    PYTHONPATH=src python -m benchmarks.run [--only fig3,fig6,...]
                                            [--out-dir artifacts/bench]
                                            [--smoke]

``--smoke`` shrinks the sweeps (sections that support it) so CI can run
a fast end-to-end pass and still upload real BENCH_*.json artifacts.

Each section's table is also written as ``BENCH_<section>.json`` (plus a
combined ``BENCH_summary.json``) so the perf trajectory can be tracked
across PRs by diffing machine-readable artifacts instead of log text.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path


def _emit(out_dir: Path, name: str, payload: dict) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"BENCH_{name}.json"
    path.write_text(json.dumps(payload, indent=2, default=str) + "\n")
    print(f"# wrote {path}", flush=True)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all",
                    help="comma list: fig3,fig6,fig7,prefix,workflow,"
                         "toolcalls,disagg,tenancy,trace,kernels,paged,"
                         "mixed,calibrate,roofline")
    ap.add_argument("--out-dir", default="artifacts/bench",
                    help="directory for BENCH_*.json summaries")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweeps for CI smoke runs")
    args = ap.parse_args()
    want = None if args.only == "all" else set(args.only.split(","))
    out_dir = Path(args.out_dir)

    summary: dict[str, dict] = {}
    names = [n for n in ("fig3", "fig6", "fig7", "prefix", "workflow",
                         "toolcalls", "disagg", "tenancy", "trace",
                         "kernels", "paged", "mixed", "calibrate",
                         "roofline")
             if want is None or n in want]
    for name in names:
        t0 = time.time()
        print(f"\n######## {name} ########", flush=True)
        report = None
        if name == "fig3":
            from benchmarks import bench_fig3
            report = bench_fig3.main(smoke=args.smoke)
        elif name == "fig6":
            from benchmarks import bench_fig6
            report = bench_fig6.main()
        elif name == "fig7":
            from benchmarks import bench_fig7
            report = bench_fig7.main()
        elif name == "prefix":
            from benchmarks import bench_prefix
            report = bench_prefix.main(smoke=args.smoke)
        elif name == "workflow":
            from benchmarks import bench_workflow
            report = bench_workflow.main(smoke=args.smoke)
        elif name == "toolcalls":
            from benchmarks import bench_toolcalls
            report = bench_toolcalls.main(smoke=args.smoke)
        elif name == "disagg":
            from benchmarks import bench_disagg
            report = bench_disagg.main(smoke=args.smoke)
        elif name == "tenancy":
            from benchmarks import bench_tenancy
            report = bench_tenancy.main(smoke=args.smoke)
        elif name == "trace":
            from benchmarks import bench_trace
            report = bench_trace.main(smoke=args.smoke,
                                      out_dir=str(out_dir))
        elif name == "kernels":
            from benchmarks import bench_kernels
            report = bench_kernels.main()
        elif name == "paged":
            from benchmarks import bench_paged_engine
            report = bench_paged_engine.main(smoke=args.smoke)
        elif name == "mixed":
            from benchmarks import bench_mixed
            report = bench_mixed.main(smoke=args.smoke)
        elif name == "calibrate":
            from benchmarks import calibrate
            report = calibrate.main(smoke=args.smoke,
                                    out_dir=str(out_dir))
        elif name == "roofline":
            from benchmarks import roofline
            if Path("artifacts/dryrun").exists():
                roofline.main()
            else:
                print("# no artifacts/dryrun — run "
                      "`python -m repro.launch.dryrun` first")
        elapsed = time.time() - t0
        if report is not None:
            print(report.render())
            payload = {**report.to_dict(), "elapsed_s": round(elapsed, 2)}
            summary[name] = payload
            _emit(out_dir, name, payload)
        print(f"# section {name} took {elapsed:.1f}s", flush=True)
    if summary:
        _emit(out_dir, "summary", summary)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
