"""Benchmark orchestrator — one section per paper figure/table plus the
kernel microbench and (if dry-run artifacts exist) the roofline tables.

    PYTHONPATH=src python -m benchmarks.run [--only fig3,fig6,...]
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all",
                    help="comma list: fig3,fig6,fig7,kernels,roofline")
    args = ap.parse_args()
    want = None if args.only == "all" else set(args.only.split(","))

    names = [n for n in ("fig3", "fig6", "fig7", "kernels", "roofline")
             if want is None or n in want]
    for name in names:
        t0 = time.time()
        print(f"\n######## {name} ########", flush=True)
        if name == "fig3":
            from benchmarks import bench_fig3
            print(bench_fig3.main().render())
        elif name == "fig6":
            from benchmarks import bench_fig6
            print(bench_fig6.main().render())
        elif name == "fig7":
            from benchmarks import bench_fig7
            print(bench_fig7.main().render())
        elif name == "kernels":
            from benchmarks import bench_kernels
            print(bench_kernels.main().render())
        elif name == "roofline":
            from benchmarks import roofline
            if Path("artifacts/dryrun").exists():
                roofline.main()
            else:
                print("# no artifacts/dryrun — run "
                      "`python -m repro.launch.dryrun` first")
        print(f"# section {name} took {time.time()-t0:.1f}s", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
