"""Stall-free mixed batching vs serialized prefill on the sim substrate.

The decode-stall pathology this measures: with serialized continuous
batching, a long prompt's prefill monopolizes whole engine steps, so
every co-resident decode stream stalls for the full prefill — inter-
token latency spikes by orders of magnitude whenever an agent with a
big context shows up.  Mixed batching (scheduler ``mixed`` knob) fuses
one budgeted prefill chunk into every live decode step instead, so the
stall is bounded by one chunk's step time.

Three configs over the same arrival trace (interactive decode streams
plus periodic long-prefill arrivals), virtual-clock deterministic:

* ``serialized`` — mixed off, one-shot prefill (the pre-ISSUE-9 path);
* ``mixed``      — mixed on, fixed ``prefill_chunk``;
* ``adaptive``   — mixed on, ``ChunkPolicy`` retuning ``prefill_chunk``
  from the engine's published ``itl_p95`` gauge (the software-defined
  knob loop).

Headline: p95 ITL and p95 TTFT reduction vs serialized, with decode
throughput (tokens per engine-busy second) held within noise — the
ISSUE-9 acceptance gate checks >=30% p95 ITL reduction at <=5% decode
throughput cost from BENCH_mixed.json.
"""
from __future__ import annotations

from benchmarks.common import Report, pctl
from repro.configs import get_config
from repro.core import Controller, MetricBus, Registry
from repro.core.metrics import CentralPoller, Collector, StateStore
from repro.core.policies import ChunkPolicy
from repro.core.types import Request
from repro.serving.engine_sim import SimEngine
from repro.serving.scheduler import SchedulerConfig
from repro.sim.clock import EventLoop
from repro.sim.costmodel import CostModel

MODEL = "agent-7b"
CHUNK = 256
ADAPTIVE_CHUNK0 = 1024        # deliberately misconfigured starting point
ITL_SLO = 0.03

# open-loop arrival trace: steady interactive streams + long prefills
INT_PERIOD, INT_PROMPT, INT_NEW = 0.06, 128, 32
LONG_PERIOD, LONG_PROMPT, LONG_NEW = 1.5, 4000, 8


def _workload(n_interactive: int, n_long: int):
    arrivals = []
    for i in range(n_interactive):
        arrivals.append((i * INT_PERIOD, INT_PROMPT, INT_NEW))
    for i in range(n_long):
        arrivals.append(((i + 1) * LONG_PERIOD, LONG_PROMPT, LONG_NEW))
    arrivals.sort()
    return arrivals


def run_cell(mode: str, n_interactive: int, n_long: int) -> dict:
    loop = EventLoop()
    cm = CostModel(get_config(MODEL))
    mixed = mode != "serialized"
    chunk0 = {"serialized": 0, "mixed": CHUNK,
              "adaptive": ADAPTIVE_CHUNK0}[mode]
    sc = SchedulerConfig(max_slots=16, num_pages=8192, max_context=8192,
                         page_size=16, max_batch_tokens=512,
                         prefill_chunk=chunk0, mixed=mixed)
    name = f"mx-{mode}"

    col = None
    if mode == "adaptive":
        bus = MetricBus()
        reg = Registry()
        store = StateStore()
        poller = CentralPoller(store)
        col = Collector("bench", bus=bus)
        poller.attach(col)

    eng = SimEngine(loop, cm, sc, name=name, collector=col)

    pol = None
    if mode == "adaptive":
        reg.register(eng)
        ctl = Controller(loop, reg, poller, interval=0.25, bus=bus)
        # clear_frac=0 disables the grow-back path: the demo is pure
        # converge-down-from-misconfiguration (growing mid-prefill would
        # re-create the stall it just removed and thrash the knob)
        pol = ChunkPolicy(name, itl_slo=ITL_SLO, chunk_min=64,
                          chunk_max=ADAPTIVE_CHUNK0, dwell=0.5,
                          clear_frac=0.0)
        ctl.install(pol)
        ctl.start()

    ttfts: list[float] = []
    gaps: list[float] = []

    def on_token(r: Request, tok: int, t: float) -> None:
        prev = r.meta.get("_bench_prev")
        r.meta["_bench_prev"] = t
        if prev is None:
            ttfts.append(t - r.arrival_time)
        else:
            gaps.append(t - prev)

    eng.on_token = on_token

    reqs = []
    for t, prompt, new in _workload(n_interactive, n_long):
        r = Request(prompt_len=prompt, max_new_tokens=new)
        reqs.append(r)
        loop.call_at(t, lambda r=r: eng.submit(r))
    loop.run_until(3600.0)                      # drain everything
    done = sum(1 for r in reqs if r.state.value == "finished")
    return {
        "done": done,
        "n": len(reqs),
        "ttft_p95": pctl(ttfts, 0.95),
        "itl_p95": pctl(gaps, 0.95),
        "itl_p50": pctl(gaps, 0.50),
        "tokens": eng.tokens_generated,
        "busy_s": eng.busy_time,
        "decode_tput": eng.tokens_generated / max(eng.busy_time, 1e-9),
        "chunk_moves": len(pol.moves) if pol else 0,
        "chunk_final": (sc.prefill_chunk if mixed else 0),
    }


def main(report: Report | None = None, smoke: bool = False) -> Report:
    rep = report or Report("mixed: stall-free batching vs serialized "
                           "prefill (sim, agent-7b roofline)")
    n_interactive, n_long = (100, 8) if smoke else (300, 24)
    cells = {m: run_cell(m, n_interactive, n_long)
             for m in ("serialized", "mixed", "adaptive")}
    base = cells["serialized"]
    for mode, r in cells.items():
        itl_red = (1.0 - r["itl_p95"] / base["itl_p95"]) * 100.0
        ttft_red = (1.0 - r["ttft_p95"] / base["ttft_p95"]) * 100.0
        tput_delta = (r["decode_tput"] / base["decode_tput"] - 1.0) * 100.0
        rep.add(f"mixed.{mode}",
                done=f"{r['done']}/{r['n']}",
                ttft_p95=f"{r['ttft_p95']:.4f}",
                itl_p95=f"{r['itl_p95']:.4f}",
                itl_p50=f"{r['itl_p50']:.4f}",
                decode_tput=f"{r['decode_tput']:.1f}",
                itl_p95_reduction_pct=f"{itl_red:.1f}",
                ttft_p95_reduction_pct=f"{ttft_red:.1f}",
                decode_tput_delta_pct=f"{tput_delta:.2f}",
                chunk_final=r["chunk_final"],
                chunk_moves=r["chunk_moves"])
    mx = cells["mixed"]
    itl_red = (1.0 - mx["itl_p95"] / base["itl_p95"]) * 100.0
    tput_delta = (mx["decode_tput"] / base["decode_tput"] - 1.0) * 100.0
    rep.note(f"acceptance: mixed itl_p95 reduction {itl_red:.1f}% "
             f"(gate >=30), decode tput delta {tput_delta:+.2f}% "
             f"(gate within 5)")
    rep.note("serialized stalls every decode stream for a whole "
             f"{LONG_PROMPT}-token prefill; mixed bounds the stall at one "
             f"{CHUNK}-token fused chunk; adaptive starts misconfigured at "
             f"{ADAPTIVE_CHUNK0} and ChunkPolicy walks the knob down off "
             "the engine's own itl_p95 gauge")
    if itl_red < 30.0:
        rep.note("WARNING: itl_p95 reduction below the 30% gate")
    if abs(tput_delta) > 5.0 and tput_delta < 0:
        rep.note("WARNING: decode throughput regressed beyond 5%")
    return rep


if __name__ == "__main__":
    print(main().render())
