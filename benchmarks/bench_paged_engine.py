"""Ring vs paged KV layout on the live Engine decode hot path.

Two questions the paged-pool refactor has to answer with numbers:

1. **Step time** — does routing decode through the shared page pool
   (gather per step, block tables as traced jit inputs) cost anything
   against the slot-contiguous ring buffers, at batch 1 and batched?
2. **Admission cost under prefix fan-out** — N agents forking from one
   shared system prompt.  Ring prefills the full prompt N times; paged
   acquires the shared pages *by id* and prefills only each request's
   private suffix — the admission-time KV copy disappears entirely.

CPU-container honesty: absolute times are interpret-mode/XLA-CPU
numbers, so the headline for (2) is *computed prefill tokens*, which is
hardware-independent, with wall time reported alongside.  The Pallas
kernel itself is benchmarked in bench_kernels; here both layouts run
the jnp paths so the comparison isolates the *layout*, not the kernel.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import Report
from repro import models
from repro.configs import get_config
from repro.core.types import Request, RequestState
from repro.serving.engine import Engine
from repro.serving.prefix_cache import PrefixCache
from repro.serving.scheduler import SchedulerConfig

PAGE = 16


def _engine(cfg, params, layout, max_slots, cache=False, num_pages=256,
            max_context=256):
    sc = SchedulerConfig(max_slots=max_slots, num_pages=num_pages,
                         max_context=max_context, page_size=PAGE)
    eng = Engine(cfg, params, sc, name=f"bench-{layout}",
                 cache_layout=layout)
    if cache:
        eng.attach_cache(PrefixCache(eng.scheduler.alloc,
                                     name=f"bench-{layout}.cache",
                                     block_tokens=PAGE, reserve_frac=0.8))
    return eng


def _req(prompt, max_new):
    return Request(prompt_len=len(prompt), max_new_tokens=max_new,
                   prompt_tokens=np.asarray(prompt, np.int32))


def _decode_step_time(cfg, params, layout, batch, prompt_len, steps):
    """Mean decode-only step time with ``batch`` co-resident sequences.

    The pool is sized to the workload's residency (as a deployment sizes
    its HBM pool): an oversized pool costs nothing on TPU (donated
    buffers update in place through the layer scan) but XLA-CPU copies
    scan-carried buffers per layer, which would charge the paged layout
    for capacity it isn't using."""
    pages = -(-(prompt_len + steps + 8) // PAGE) * batch
    eng = _engine(cfg, params, layout, max_slots=batch, num_pages=pages,
                  max_context=128)
    rng = np.random.default_rng(0)
    reqs = [_req(rng.integers(0, cfg.vocab, prompt_len), steps + 4)
            for _ in range(batch)]
    for r in reqs:
        eng.submit(r)
    while any(r.prefilled < r.prompt_len for r in reqs):
        eng.step()
    eng.step()                      # warm the decode jit (trace + compile)
    t0 = time.perf_counter()
    for _ in range(steps):
        eng.step()
    return (time.perf_counter() - t0) / steps


def _fanout_admission(cfg, params, layout, fanout, shared_len, suffix_len):
    """Admit ``fanout`` requests forking from one shared prefix; return
    (computed prefill tokens, admission+prefill wall seconds)."""
    pages = -(-(shared_len + suffix_len + 8) // PAGE) * (fanout + 1)
    eng = _engine(cfg, params, layout, max_slots=fanout,
                  cache=(layout == "paged"), num_pages=pages,
                  max_context=128)
    rng = np.random.default_rng(1)
    shared = rng.integers(0, cfg.vocab, shared_len)
    reqs = []
    for i in range(fanout):
        suffix = rng.integers(0, cfg.vocab, suffix_len)
        reqs.append(_req(np.concatenate([shared, suffix]), 2))
    # warm both prefill shapes (full prompt + cached-fork suffix) on a
    # throwaway prefix so the timed sweep measures steps, not jit traces
    warm = rng.integers(0, cfg.vocab, shared_len)
    for _ in range(2):
        w = _req(np.concatenate([warm, rng.integers(0, cfg.vocab,
                                                    suffix_len)]), 2)
        eng.submit(w)
        eng.run_until_idle()
    computed = 0
    t0 = time.perf_counter()
    for r in reqs:                  # sequential arrivals: later requests
        eng.submit(r)               # see the earlier ones' shared pages
        while r.prefilled < r.prompt_len:
            eng.step()
        computed += r.prompt_len - r.meta.get("cached_prompt_tokens", 0)
    wall = time.perf_counter() - t0
    eng.run_until_idle()
    assert all(r.state == RequestState.FINISHED for r in reqs)
    return computed, wall


def main(smoke: bool = False) -> Report:
    rep = Report("paged vs ring KV layout (live engine)")
    cfg = get_config("tiny-agent").replace(dtype="float32")
    params = models.init(cfg, jax.random.key(0))

    steps = 10 if smoke else 40
    for batch in ([1] if smoke else [1, 4]):
        times = {}
        for layout in ("ring", "paged"):
            times[layout] = _decode_step_time(cfg, params, layout,
                                              batch=batch, prompt_len=48,
                                              steps=steps)
        rep.add(f"decode_b{batch}",
                ring_ms=round(times["ring"] * 1e3, 3),
                paged_ms=round(times["paged"] * 1e3, 3),
                paged_over_ring=round(times["paged"] / times["ring"], 3))

    shared_len, suffix_len = 96, 16
    for fanout in ([4] if smoke else [2, 4, 8]):
        row = {}
        for layout in ("ring", "paged"):
            toks, wall = _fanout_admission(cfg, params, layout, fanout,
                                           shared_len, suffix_len)
            row[f"{layout}_prefill_tokens"] = toks
            row[f"{layout}_admit_s"] = round(wall, 3)
        full = fanout * (shared_len + suffix_len)
        rep.add(f"fanout_{fanout}", **row,
                token_reduction=round(
                    1.0 - row["paged_prefill_tokens"] / full, 3))
    rep.note(f"shared prefix {shared_len} tok, private suffix "
             f"{suffix_len} tok; paged admits later forks by page id "
             f"(zero KV copies), ring recomputes the full prompt")
    rep.note("CPU-container numbers: token_reduction is the "
             "hardware-independent headline; wall times are XLA-CPU")
    return rep


if __name__ == "__main__":
    print(main().render())
