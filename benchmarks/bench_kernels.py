"""Kernel microbench: correctness deltas vs oracle + analytic kernel
roofline (VMEM working set, arithmetic intensity, projected v5e time).

This container has no TPU: wall-clock numbers here would measure the
Python interpreter, not the kernel.  What we CAN report honestly per
kernel/shape is (a) max |err| vs the pure-jnp oracle in interpret mode,
(b) the BlockSpec working set vs the 16 MB/core VMEM budget, and (c)
the roofline-projected v5e time from exact FLOP/byte counts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Report
from repro.kernels import ops, ref

PEAK = 197e12
HBM = 819e9
VMEM = 16 * 2**20


def _proj(flops, bytes_):
    return max(flops / PEAK, bytes_ / HBM)


def flash_attention_row(rep, b, s, h, hkv, dh, blk=128):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, dh), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = jnp.moveaxis(ref.flash_attention_ref(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
        jnp.moveaxis(v, 2, 1), causal=True), 1, 2)
    err = float(jnp.abs(out - want).max())
    dhp = max(dh, 128)
    vmem = (blk * dhp * 3 + blk * blk + blk * dhp) * 4
    flops = 4.0 * b * h * s * s * dh / 2            # causal half
    bytes_ = (q.size + k.size + v.size + out.size) * 2   # bf16 on TPU
    rep.add(f"kernels.flash_attention.b{b}s{s}h{h}kv{hkv}d{dh}",
            max_err=f"{err:.2e}",
            vmem_kb=vmem // 1024, vmem_ok=vmem < VMEM,
            intensity=f"{flops/bytes_:.0f}",
            v5e_us=f"{_proj(flops, bytes_)*1e6:.1f}")


def decode_attention_row(rep, b, h, hkv, dh, t, blk=128):
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (b, 1, h, dh), jnp.float32)
    ck = jax.random.normal(ks[1], (b, t, hkv, dh), jnp.float32)
    cv = jax.random.normal(ks[2], (b, t, hkv, dh), jnp.float32)
    kpos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    qp = jnp.full((b,), t - 1)
    out = ops.decode_attention(q, ck, cv, kpos, qp, interpret=True)
    want = ref.decode_attention_ref(
        q.reshape(b, hkv, h // hkv, dh), jnp.moveaxis(ck, 2, 1),
        jnp.moveaxis(cv, 2, 1), kpos, qp[:, None])
    err = float(jnp.abs(out.reshape(b, hkv, h // hkv, dh) - want).max())
    flops = 4.0 * b * h * t * dh
    bytes_ = (ck.size + cv.size) * 2                 # KV read dominates
    g = h // hkv
    vmem = (max(g, 8) * max(dh, 128) + 2 * blk * max(dh, 128)) * 4
    rep.add(f"kernels.decode_attention.b{b}h{h}kv{hkv}d{dh}t{t}",
            max_err=f"{err:.2e}",
            vmem_kb=vmem // 1024, vmem_ok=vmem < VMEM,
            intensity=f"{flops/bytes_:.1f}",
            v5e_us=f"{_proj(flops, bytes_)*1e6:.1f}")


def paged_decode_attention_row(rep, b, h, hkv, dh, page, per_seq, shared):
    """Block-table-indirected decode over a shared page pool: ``shared``
    prefix pages are the *same* physical ids in every row, so the pool
    holds (and HBM reads) one copy of the prefix per step."""
    n = shared + b * (per_seq - shared)
    ks = jax.random.split(jax.random.key(5), 3)
    q = jax.random.normal(ks[0], (b, 1, h, dh), jnp.float32)
    k_pages = jax.random.normal(ks[1], (n, page, hkv, dh), jnp.float32)
    v_pages = jax.random.normal(ks[2], (n, page, hkv, dh), jnp.float32)
    rows, nxt = [], shared
    for _ in range(b):
        rows.append(list(range(shared))
                    + list(range(nxt, nxt + per_seq - shared)))
        nxt += per_seq - shared
    bt = jnp.asarray(rows, jnp.int32)
    ctx = jnp.asarray([per_seq * page - 1 - 5 * i for i in range(b)],
                      jnp.int32)
    g = h // hkv
    out = ops.paged_decode_attention(q, k_pages, v_pages, bt, ctx,
                                     interpret=True)
    want = ref.paged_decode_attention_ref(q.reshape(b, hkv, g, dh),
                                          k_pages, v_pages, bt, ctx)
    err = float(jnp.abs(out.reshape(b, hkv, g, dh) - want).max())
    t = per_seq * page
    flops = 4.0 * b * h * t * dh
    # unique pages read once: shared prefix pages are not re-read per row
    uniq_toks = n * page
    bytes_ = 2 * uniq_toks * hkv * dh * 2 * 2        # K+V, bf16
    dhp = max(dh, 128)
    vmem = (max(g, 8) * dhp + 2 * page * dhp) * 4
    rep.add(f"kernels.paged_decode_attention."
            f"b{b}h{h}kv{hkv}d{dh}p{page}x{per_seq}s{shared}",
            max_err=f"{err:.2e}",
            vmem_kb=vmem // 1024, vmem_ok=vmem < VMEM,
            shared_read_saving=f"{1 - uniq_toks/(b*t):.0%}",
            v5e_us=f"{_proj(flops, bytes_)*1e6:.1f}")


def grouped_matmul_row(rep, e, c, d, f):
    ks = jax.random.split(jax.random.key(2), 2)
    x = jax.random.normal(ks[0], (e, c, d), jnp.float32)
    w = jax.random.normal(ks[1], (e, d, f), jnp.float32)
    counts = jnp.array([c] * (e // 2) + [0] * (e - e // 2))
    out = ops.grouped_matmul(x, w, counts, interpret=True)
    want = ref.grouped_matmul_ref(x, w, counts)
    err = float(jnp.abs(out - want).max())
    live = e // 2
    flops = 2.0 * live * c * d * f                  # empty experts skipped
    bytes_ = (live * c * d + live * d * f + live * c * f) * 2
    vmem = (128 * 128 * 3 + 128 * 128) * 4
    rep.add(f"kernels.grouped_matmul.e{e}c{c}d{d}f{f}",
            max_err=f"{err:.2e}", vmem_kb=vmem // 1024, vmem_ok=True,
            skip_saving=f"{e//2}/{e} experts idle",
            v5e_us=f"{_proj(flops, bytes_)*1e6:.1f}")


def ssm_scan_row(rep, b, h, t, dk, dv, chunk=128):
    ks = jax.random.split(jax.random.key(3), 4)
    q = jax.random.normal(ks[0], (b, t, h, dk)) * 0.3
    k = jax.random.normal(ks[1], (b, t, h, dk)) * 0.3
    v = jax.random.normal(ks[2], (b, t, h, dv)) * 0.3
    la = -jax.random.uniform(ks[3], (b, t, h)) * 0.1
    h0 = jnp.zeros((b, h, dk, dv))
    y, hT = ops.ssm_scan(q, k, v, la, h0, chunk=min(chunk, t),
                         interpret=True)
    yr, hr = ref.ssm_scan_ref(jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
                              jnp.moveaxis(v, 2, 1),
                              jnp.moveaxis(la, 2, 1)[..., None], h0)
    err = float(jnp.abs(y - jnp.moveaxis(yr, 1, 2)).max())
    c = min(chunk, t)
    flops = b * h * t * (4 * dk * dv + 2 * c * dk + 2 * c * dv)
    bytes_ = (q.size + k.size + v.size + y.size) * 2
    vmem = (3 * c * max(dk, 128) + c * c + dk * dv) * 4
    rep.add(f"kernels.ssm_scan.b{b}h{h}t{t}dk{dk}dv{dv}",
            max_err=f"{err:.2e}",
            vmem_kb=vmem // 1024, vmem_ok=vmem < VMEM,
            v5e_us=f"{_proj(flops, bytes_)*1e6:.1f}")


def main(report: Report | None = None) -> Report:
    rep = report or Report("kernels: oracle deltas + v5e roofline")
    flash_attention_row(rep, 1, 512, 8, 2, 128)
    flash_attention_row(rep, 2, 256, 4, 4, 64)
    decode_attention_row(rep, 4, 8, 2, 128, 1024)
    decode_attention_row(rep, 2, 4, 4, 64, 256)
    paged_decode_attention_row(rep, 4, 8, 2, 128, 128, 8, 4)
    paged_decode_attention_row(rep, 2, 4, 4, 64, 16, 4, 0)
    grouped_matmul_row(rep, 8, 128, 256, 512)
    ssm_scan_row(rep, 1, 4, 256, 64, 64)
    rep.note("kernels: interpret-mode correctness vs ref.py oracle; "
             "VMEM working sets within the 16MB/core budget; v5e time "
             "is the analytic roofline projection (no TPU in container)")
    return rep


if __name__ == "__main__":
    print(main().render())
