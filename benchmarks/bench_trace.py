"""Tracing plane benchmark: overhead + exporter smoke.

Two questions:

1. **What does always-on tracing cost?**  The fig1 pipeline runs the
   same task burst with tracing off and with ``sample=1.0``; the table
   reports makespan and wall-time deltas (spans are plain dataclass
   appends on the virtual-time hot path, so both should be ~0).
2. **Do the exports hold their contract?**  A fig1 run and a workflow
   (deep_review) run are exported as ``TRACE_fig1.json`` /
   ``TRACE_workflow.json`` into the artifact directory; the section
   checks Chrome-trace validity, segment-sum-vs-e2e tiling (the <=1%
   acceptance bound), and that at least one control-plane action is
   causally linked — the same files CI uploads and schema-gates.

    PYTHONPATH=src python benchmarks/bench_trace.py [--smoke]
"""
from __future__ import annotations

import importlib.util
import sys
import time
from pathlib import Path

# runnable both as `python -m benchmarks.run --only trace` and directly
_ROOT = Path(__file__).resolve().parent.parent
for p in (str(_ROOT), str(_ROOT / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

from benchmarks.common import Report  # noqa: E402
from repro.agents import (AgenticPipeline, PipelineConfig, TaskSpec,
                          WorkflowConfig, deep_review)  # noqa: E402
from repro.agents.workloads import GraphBurst  # noqa: E402
from repro.core.intent import compile_intent  # noqa: E402


def _report_tool():
    path = _ROOT / "tools" / "trace_report.py"
    spec = importlib.util.spec_from_file_location("trace_report", path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules[spec.name] = mod
    spec.loader.exec_module(mod)
    return mod


INTENT = """
rule widen on developer.queue_len > 2 hold 1:
    => set developer.max_num_seqs 48; note widened under burst
"""


def _run_fig1(n_tasks: int, traced: bool):
    pipe = AgenticPipeline(PipelineConfig(n_testers=2))
    pipe.controller.install(compile_intent(INTENT))
    if traced:
        pipe.tracer.set_scope(None, 1.0)
    for i in range(n_tasks):
        pipe.submit(TaskSpec(session=f"s{i}", n_functions=4))
    t0 = time.perf_counter()
    pipe.run(until=240.0)
    wall = time.perf_counter() - t0
    assert len(pipe.done) == n_tasks, f"{len(pipe.done)}/{n_tasks} done"
    makespan = max(s.finished_at for s in pipe.done)
    return pipe, makespan, wall


def _check_export(rpt, pipe, path: Path, rep: Report, label: str,
                  want_links: bool) -> None:
    doc = pipe.tracer.export(path, recorder=pipe.recorder)
    loaded = rpt.load(path)
    errors = rpt.validate(loaded)
    assert errors == [], f"{label}: invalid chrome trace: {errors[:3]}"
    checks = rpt.decomposition_check(rpt.spans_from(loaded))
    assert checks, f"{label}: no closed request spans"
    worst = max(abs(tot - dur) / max(dur, 1e-9) for _, tot, dur in checks)
    assert worst <= 0.01, f"{label}: segment tiling off by {worst:.2%}"
    links = doc["otherData"]["links"]
    if want_links:
        assert links >= 1, f"{label}: no causally-linked action"
    rep.add(f"export_{label}", spans=doc["otherData"]["spans"],
            actions=doc["otherData"]["actions"], links=links,
            requests=len(checks), worst_tiling=f"{worst:.4%}",
            file=path.name)


def main(smoke: bool = False, out_dir: str = "artifacts/bench"):
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    rpt = _report_tool()
    rep = Report("tracing plane: overhead + export contract")
    n_tasks = 4 if smoke else 12

    _, mk_off, wall_off = _run_fig1(n_tasks, traced=False)
    pipe, mk_on, wall_on = _run_fig1(n_tasks, traced=True)
    rep.add("fig1_untraced", tasks=n_tasks, makespan=f"{mk_off:.3f}",
            wall_s=f"{wall_off:.2f}")
    rep.add("fig1_traced", tasks=n_tasks, makespan=f"{mk_on:.3f}",
            wall_s=f"{wall_on:.2f}",
            makespan_delta=f"{(mk_on - mk_off) / mk_off:+.3%}",
            spans=pipe.tracer.spans_total)
    assert abs(mk_on - mk_off) <= 1e-9 * max(mk_off, 1.0), (
        "tracing changed the virtual-time schedule")
    _check_export(rpt, pipe, out / "TRACE_fig1.json", rep, "fig1",
                  want_links=True)

    # workflow DAG: stage spans + critical path from the export alone
    wf = AgenticPipeline.build(
        deep_review(depth=2 if smoke else 4),
        WorkflowConfig(router_policy="least_loaded"))
    wf.tracer.set_scope(None, 1.0)
    GraphBurst(wf, n_tasks=2 if smoke else 6).start()
    wf.run(until=240.0)
    assert wf.done, "workflow run finished no tasks"
    _check_export(rpt, wf, out / "TRACE_workflow.json", rep, "workflow",
                  want_links=False)
    path = rpt.critical_path(rpt.spans_from(rpt.load(
        out / "TRACE_workflow.json")), wf.done[0].task_id)
    rep.add("workflow_critical_path", hops=len(path),
            chain=">".join(s.name.split(":", 1)[-1] for s in path))
    assert len(path) >= 2, "critical path did not chain stages"

    rep.note("segment tiling bound: |sum(segments) - e2e| <= 1% per request")
    rep.note("trace artifacts: TRACE_fig1.json TRACE_workflow.json "
             "(chrome://tracing / ui.perfetto.dev)")
    return rep


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    print(main(smoke=smoke).render())
